"""Runtime telemetry: recorder semantics, overlay export, divergence join.

Four layers of guarantees:

* the recorder's structured spans nest/close correctly (SpanError on
  misuse), the disabled path is a cached no-op, and the ``interval``
  primitive reads the clock exactly twice whether or not recording is
  enabled — so instrumented measurements are bit-identical to the ad-hoc
  ``perf_counter`` arithmetic they replaced;
* the exported JSON is byte-identical across processes with different
  ``PYTHONHASHSEED`` values (same convention as the serve determinism
  gate);
* the executor span vocabulary (``repro.dist.pp.schedule_span_names``)
  and the simulated graph's node set are the same names on the same
  devices — the join key the divergence attributor relies on;
* the attributor itself: a clean join is silent with full gap
  attribution, and each O code fires on its tampered corpus.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core.simulator import SimEvent, SimResult
from repro.core.strategy import LayerCost, Strategy, pipeline_graph
from repro.core.timeline import _device_sort_key, to_chrome_trace
from repro.dist.pp import schedule_span_names
from repro.obs import (
    Counter,
    Recorder,
    SpanError,
    derive_sim_counters,
    divergence_report,
    overlay_chrome_trace,
)
from repro.obs.record import _NULL_SPAN
from repro.pricing import PROV_DB

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


class FakeClock:
    """Deterministic clock: returns 0.0, 1.0, 2.0, ... and counts reads."""

    def __init__(self):
        self.reads = 0

    def __call__(self) -> float:
        t = float(self.reads)
        self.reads += 1
        return t


# -- recorder semantics --------------------------------------------------------


def test_nested_spans_record_depth_and_close_order():
    clk = FakeClock()
    rec = Recorder(clock=clk)
    rec.begin("outer", "host")
    rec.begin("inner", "stage0", kind="fwd", mb=3)
    rec.end("inner")
    rec.end("outer")
    assert [s.name for s in rec.spans] == ["inner", "outer"]
    inner, outer = rec.spans
    assert (inner.depth, outer.depth) == (1, 0)
    assert inner.device == "stage0" and inner.kind == "fwd"
    assert inner.labels == {"mb": 3}
    assert outer.start < inner.start < inner.end < outer.end
    assert rec.open_spans == []


def test_mismatched_close_raises():
    rec = Recorder(clock=FakeClock())
    rec.begin("a")
    with pytest.raises(SpanError, match="mismatched"):
        rec.end("b")


def test_end_with_no_open_span_raises():
    rec = Recorder(clock=FakeClock())
    with pytest.raises(SpanError, match="no open span"):
        rec.end("a")


def test_export_with_open_span_raises():
    rec = Recorder(clock=FakeClock())
    rec.begin("half-open")
    with pytest.raises(SpanError, match="half-open"):
        rec.to_events()


def test_span_context_manager_matches_begin_end():
    rec = Recorder(clock=FakeClock())
    with rec.span("a", "stage1", kind="fwd"):
        with rec.span("b"):
            pass
    assert [(s.name, s.depth) for s in rec.spans] == [("b", 1), ("a", 0)]


# -- disabled fast path --------------------------------------------------------


def test_disabled_span_returns_cached_singleton():
    rec = Recorder(enabled=False, clock=FakeClock())
    assert rec.span("a") is rec.span("b") is _NULL_SPAN
    with rec.span("a"):
        pass


def test_disabled_recorder_records_nothing_and_never_reads_clock():
    clk = FakeClock()
    rec = Recorder(enabled=False, clock=clk)
    rec.begin("a")
    rec.end("a")  # no SpanError: disabled end is a no-op, not a close
    rec.emit("b", "chip", 0.0, 1.0)
    rec.counter("c", "chip", 5.0)
    with rec.span("d"):
        pass
    assert rec.spans == [] and rec.counters == []
    assert rec.to_events() == []
    assert clk.reads == 0


def test_interval_reads_clock_exactly_twice_enabled_or_not():
    for enabled in (True, False):
        clk = FakeClock()
        rec = Recorder(enabled=enabled, clock=clk)
        iv = rec.interval("step", "host", role="step")
        assert clk.reads == 1
        dur = iv.stop()
        assert clk.reads == 2
        # endpoints are the raw clock readings: bit-identical to the
        # ad-hoc t1 - t0 arithmetic this primitive replaced
        assert dur == 1.0
        assert len(rec.spans) == (1 if enabled else 0)


def test_interval_duration_bit_identical_across_enabled_states():
    """Same scripted clock -> the measured float is the same object-level
    value with recording on or off (the PR-7 replay-parity invariant)."""
    times = [0.1234567891234, 0.9876543219876]

    def mk():
        it = iter(times)
        return lambda: next(it)

    durs = []
    for enabled in (True, False):
        rec = Recorder(enabled=enabled, clock=mk())
        durs.append(rec.interval("s").stop())
    assert durs[0] == durs[1] == times[1] - times[0]


# -- deterministic export ------------------------------------------------------

_EXPORT_SCRIPT = """
from repro.obs.record import Recorder

times = iter(float(i) for i in range(100))
rec = Recorder(clock=lambda: next(times))
for i in range(3):
    rec.begin(f"train_step{i}", "host", role="step", step=i)
    rec.emit(f"F0.{i}", "stage0", 10.0 + i, 10.5 + i, kind="fwd",
             zeta=1, alpha=2, mid=3)
    rec.counter("kv_free_blocks", "chip", 40.0 - i)
    rec.end(f"train_step{i}")
print(rec.to_json())
"""


def test_export_json_identical_across_hash_seeds():
    """Byte-identical telemetry JSON across processes with different
    PYTHONHASHSEED values (dict/label ordering must not leak in)."""
    outs = []
    for hash_seed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hash_seed
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run(
            [sys.executable, "-c", _EXPORT_SCRIPT], env=env,
            capture_output=True, text=True, timeout=120,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        outs.append(out.stdout)
    assert outs[0] == outs[1]
    doc = json.loads(outs[0])
    assert doc["schema"] == "repro.obs/1"
    assert len(doc["spans"]) == 6 and len(doc["counters"]) == 3


# -- span vocabulary vs the simulated graph ------------------------------------


@pytest.mark.parametrize("schedule,vstages", [
    ("gpipe", 1), ("1f1b", 1), ("interleaved_1f1b", 2),
])
def test_schedule_span_names_match_pipeline_graph(schedule, vstages):
    """The executor-side vocabulary IS the graph's node set: every
    compute/send node uid and device, no extras, no omissions."""
    strat = Strategy(pp=4, microbatches=8, schedule=schedule,
                     vstages=vstages)
    g = pipeline_graph(
        8, LayerCost(fwd_flops=1e6, fwd_bytes=1e4, boundary_bytes=64),
        strat,
    )
    graph_named = {
        (n.name, n.device) for n in g.nodes
        if n.kind in ("fwd", "bwd", "collective-permute")
    }
    spans = schedule_span_names(strat.make_pipeline_schedule())
    assert len(spans) == len(set(spans))
    assert set(spans) == graph_named


# -- timeline counter tracks (satellite 1) -------------------------------------


def _sim(events):
    busy: dict[str, float] = {}
    for e in events:
        busy[e.device] = busy.get(e.device, 0.0) + (e.end - e.start)
    return SimResult(
        makespan=max((e.end for e in events), default=0.0),
        device_busy=busy, events=events, time_by_kind={},
    )


def test_device_sort_key_orders_compute_slots_links_counters():
    devs = ["ctr:kv_free", "link:pp", "slot1", "stage1", "chip", "slot0",
            "stage0", "host", "link:dp0", "weird"]
    assert sorted(devs, key=_device_sort_key) == [
        "chip", "host", "stage0", "stage1", "slot0", "slot1",
        "link:dp0", "link:pp", "weird", "ctr:kv_free",
    ]


def test_to_chrome_trace_emits_counter_tracks():
    res = _sim([SimEvent(0, "F0.0", "fwd", "stage0", 0.0, 1.0)])
    trace = to_chrome_trace(
        res, counters=[Counter("kv_free", "chip", 0.5, 7.0),
                       ("kv_free", 0.75, 6.0)],
    )
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e["name"] == "process_name"}
    assert names == {"stage0", "ctr:kv_free"}
    cs = [e for e in trace["traceEvents"] if e["ph"] == "C"]
    assert [c["args"]["kv_free"] for c in cs] == [7.0, 6.0]
    # counter pid sorts after every device pid
    stage_pid = next(e["pid"] for e in trace["traceEvents"]
                     if e["ph"] == "X")
    assert all(c["pid"] > stage_pid for c in cs)


# -- overlay export ------------------------------------------------------------


def _overlay_fixture():
    events = [
        SimEvent(0, "F0.0", "fwd", "stage0", 0.0, 1.0),
        SimEvent(1, "sendF0.0", "collective-permute", "link:pp", 1.0, 1.2),
        SimEvent(2, "F1.0", "fwd", "stage1", 1.2, 2.2),
        SimEvent(3, "B1.0", "bwd", "stage1", 2.2, 4.2),
        SimEvent(4, "B0.0", "bwd", "stage0", 4.4, 6.4),
    ]
    rec = Recorder(clock=FakeClock())
    # the real side starts at an arbitrary wall-clock offset
    rec.emit("F0.0", "stage0", 100.0, 101.1, kind="fwd")
    rec.emit("F1.0", "stage1", 101.3, 102.5, kind="fwd")
    rec.counter("live_slots", "chip", 2.0, t=100.5)
    return _sim(events), rec


def test_overlay_tracks_sim_above_real_per_device():
    res, rec = _overlay_fixture()
    trace = overlay_chrome_trace(res, rec)
    label_by_pid = {
        e["pid"]: e["args"]["name"] for e in trace["traceEvents"]
        if e["name"] == "process_name"
    }
    labels = [label_by_pid[p] for p in sorted(label_by_pid)]
    # same device adjacent, sim first; counter tracks last
    assert labels.index("sim:stage0") + 1 == labels.index("real:stage0")
    assert labels.index("sim:stage1") + 1 == labels.index("real:stage1")
    assert labels[-1].startswith(("sim:ctr:", "real:ctr:"))
    assert "real:ctr:live_slots" in labels


def test_overlay_sides_t0_normalized_independently():
    res, rec = _overlay_fixture()
    trace = overlay_chrome_trace(res, rec)
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    label_by_pid = {
        e["pid"]: e["args"]["name"] for e in trace["traceEvents"]
        if e["name"] == "process_name"
    }
    sim_ts = [e["ts"] for e in xs if label_by_pid[e["pid"]].startswith("sim:")]
    real_ts = [e["ts"] for e in xs
               if label_by_pid[e["pid"]].startswith("real:")]
    assert min(sim_ts) == 0.0 and min(real_ts) == 0.0
    # the real 100s offset must not survive normalization
    assert max(real_ts) < 10e6


def test_overlay_attaches_provenance_and_labels_as_args():
    res, rec = _overlay_fixture()
    g = pipeline_graph(
        2, LayerCost(fwd_flops=1e6, fwd_bytes=1e4, boundary_bytes=64),
        Strategy(pp=2, microbatches=1),
    )
    for n in g.nodes:
        n.meta["time_provenance"] = PROV_DB
    trace = overlay_chrome_trace(res, rec, graph=g)
    by_name: dict[str, list] = {}
    for e in trace["traceEvents"]:
        if e["ph"] == "X":
            by_name.setdefault(e["name"], []).append(e)
    send = by_name["sendF0.0"][0]
    assert send["args"]["time_provenance"] == PROV_DB
    assert send["args"]["comm_bytes"] == 64
    real_f = [e for e in by_name["F0.0"] if "args" not in e or
              "time_provenance" not in e.get("args", {})]
    assert real_f, "real span lost its own event"


def test_derive_sim_counters_tracks_inflight_and_link_concurrency():
    res, _ = _overlay_fixture()
    ctrs = derive_sim_counters(res)
    inflight = [(c.t, c.value) for c in ctrs
                if c.name == "inflight_microbatches"]
    # one microbatch: +1 at first F start, -1 at last B end
    assert inflight == [(0.0, 1.0), (6.4, 0.0)]
    link = [(c.t, c.value) for c in ctrs if c.name == "link_concurrency"]
    assert link == [(1.0, 1.0), (1.2, 0.0)]


def test_overlay_real_only_is_valid():
    _, rec = _overlay_fixture()
    trace = overlay_chrome_trace(None, rec)
    assert any(e["ph"] == "X" for e in trace["traceEvents"])


# -- divergence attribution (tamper corpus) ------------------------------------


def _joined_fixture():
    """Real and sim sides that join perfectly on 3 uids."""
    events = [
        SimEvent(0, "F0.0", "fwd", "stage0", 0.0, 1.0),
        SimEvent(1, "sendF0.0", "collective-permute", "link:pp", 1.0, 1.5),
        SimEvent(2, "B0.0", "bwd", "stage0", 1.5, 3.5),
    ]
    spans = [
        {"name": "F0.0", "device": "stage0", "start": 0.0, "end": 1.2,
         "kind": "fwd", "labels": {}},
        {"name": "sendF0.0", "device": "link:pp", "start": 1.2, "end": 1.8,
         "kind": "collective-permute", "labels": {}},
        {"name": "B0.0", "device": "stage0", "start": 1.8, "end": 4.0,
         "kind": "bwd", "labels": {}},
    ]
    return _sim(events), spans


def _codes(report):
    return sorted(d.code for d in report.findings)


def test_clean_join_full_attribution_no_warnings():
    res, spans = _joined_fixture()
    rep = divergence_report(spans, res)
    assert _codes(rep) == ["O000"]
    assert rep.ok
    m = rep.metrics
    assert m["obs_gap_attributed_frac"] == 1.0
    assert m["obs_joined_ops"] == 3.0
    assert m["obs_unmatched_real"] == m["obs_unmatched_sim"] == 0.0
    assert m["obs_gap_s"] == pytest.approx(4.0 - 3.5)
    rows = rep.extras["obs_diff"]["rows"]
    assert rows[0]["abs_err_s"] == max(r["abs_err_s"] for r in rows)


def test_bogus_real_span_fires_o001():
    res, spans = _joined_fixture()
    spans.append({"name": "mystery_op", "device": "stage0",
                  "start": 4.0, "end": 4.5, "kind": "fwd", "labels": {}})
    rep = divergence_report(spans, res)
    o1 = [d for d in rep.findings if d.code == "O001"]
    assert len(o1) == 1 and "mystery_op" in o1[0].message
    assert rep.metrics["obs_gap_attributed_frac"] < 1.0


def test_unobserved_sim_node_fires_o002():
    res, spans = _joined_fixture()
    del spans[1]  # the send was never measured
    rep = divergence_report(spans, res)
    o2 = [d for d in rep.findings if d.code == "O002"]
    assert len(o2) == 1 and "sendF0.0" in o2[0].message
    assert rep.metrics["obs_unmatched_sim"] == 1.0


def test_class_error_over_tolerance_fires_o003():
    res, spans = _joined_fixture()
    g = pipeline_graph(
        2, LayerCost(fwd_flops=1e6, fwd_bytes=1e4, boundary_bytes=64),
        Strategy(pp=2, microbatches=1),
    )
    for n in g.nodes:
        n.meta["time_provenance"] = PROV_DB
    spans[0]["end"] = spans[0]["start"] + 50.0  # 50x the priced second
    rep = divergence_report(spans, res, g)
    o3 = [d for d in rep.findings if d.code == "O003"]
    assert len(o3) == 1 and PROV_DB in o3[0].message
    # same corpus under a loose bound is silent
    rep2 = divergence_report(spans, res, g,
                             class_tolerances={PROV_DB: 100.0})
    assert not [d for d in rep2.findings if d.code == "O003"]


def test_structural_step_spans_excluded_from_join():
    res, spans = _joined_fixture()
    spans.append({"name": "train_step0", "device": "host",
                  "start": 0.0, "end": 9.0, "kind": "train-step",
                  "labels": {"role": "step"}})
    rep = divergence_report(spans, res)
    assert not [d for d in rep.findings if d.code == "O001"]
    assert rep.metrics["obs_step_total_s"] == pytest.approx(9.0)
    # the step wrapper's dispatch overhead never enters the op gap
    assert rep.metrics["obs_measured_s"] == pytest.approx(4.0)


def test_o001_findings_capped_with_overflow_summary():
    res, spans = _joined_fixture()
    for i in range(12):
        spans.append({"name": f"ghost{i}", "device": "host",
                      "start": 5.0 + i, "end": 5.5 + i, "kind": "x",
                      "labels": {}})
    rep = divergence_report(spans, res)
    o1 = [d for d in rep.findings if d.code == "O001"]
    assert len(o1) == 9  # 8 itemized + 1 overflow summary
    assert "4 more" in o1[-1].message
    assert rep.metrics["obs_unmatched_real"] == 12.0


def test_divergence_report_accepts_recorder():
    res, _ = _joined_fixture()
    rec = Recorder(clock=FakeClock())
    rec.emit("F0.0", "stage0", 0.0, 1.1, kind="fwd")
    rec.emit("sendF0.0", "link:pp", 1.1, 1.6, kind="collective-permute")
    rec.emit("B0.0", "stage0", 1.6, 3.7, kind="bwd")
    rep = divergence_report(rec, res)
    assert rep.metrics["obs_joined_ops"] == 3.0
    assert rep.metrics["obs_gap_attributed_frac"] == 1.0


def test_divergence_report_importable_from_analysis():
    """The lazy re-export keeps the analysis facade circular-import-safe."""
    import repro.analysis as analysis

    assert analysis.divergence_report is divergence_report


# -- bench_gate drift table (satellite 2) --------------------------------------


def _bench_gate():
    sys.path.insert(0, os.path.join(REPO, "scripts"))
    try:
        import bench_gate
    finally:
        sys.path.pop(0)
    return bench_gate


def test_drift_table_statuses_cover_all_transitions():
    bg = _bench_gate()
    baseline = {
        "ok_metric": {"value": 10.0, "tol_abs": 1.0},
        "fail_metric": {"value": 5.0, "tol_rel": 0.1},
        "gone_metric": {"value": 1.0},
    }
    current = {
        "ok_metric": {"value": 10.5},
        "fail_metric": {"value": 6.0},
        "new_metric": {"value": 3.0},
    }
    rows = {r["name"]: r for r in bg.drift_table(current, baseline)}
    assert rows["ok_metric"]["status"] == "ok"
    assert rows["fail_metric"]["status"] == "fail"
    assert rows["gone_metric"]["status"] == "missing"
    assert rows["new_metric"]["status"] == "new"
    assert rows["fail_metric"]["diff"] == pytest.approx(1.0)
    assert rows["fail_metric"]["tol"] == pytest.approx(0.5)
    # --smoke mode downgrades missing to skipped
    smoke = {r["name"]: r for r in
             bg.drift_table(current, baseline, allow_missing=True)}
    assert smoke["gone_metric"]["status"] == "skipped"
    # compare() derives its verdict from the same rows
    failures = bg.compare(current, baseline,
                          rows=list(rows.values()))
    assert len(failures) == 2  # fail_metric + gone_metric


def test_render_drift_aligned_table():
    bg = _bench_gate()
    rows = bg.drift_table({"m": {"value": 2.0}},
                          {"m": {"value": 1.0, "tol_abs": 0.5}})
    out = bg.render_drift(rows)
    lines = out.splitlines()
    assert lines[0].startswith("metric")
    assert set(lines[1]) <= {"-", " "}
    assert "fail" in lines[2]
    assert len({len(l) for l in lines[:2]}) == 1
