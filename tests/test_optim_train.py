"""Optimizers vs hand math; train-step semantics (accum equivalence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_shape, smoke_variant
from repro.models import build_model, make_concrete_batch
from repro.optim import (
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_with_warmup,
    global_norm,
)
from repro.train import make_train_step
from repro.train.step import init_state


def test_adamw_matches_hand_step():
    opt = adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    state = opt.init(p)
    upd, state = opt.update(g, state, p, lr=0.1)
    m = 0.1 * np.asarray([0.5, 0.25])
    v = 0.001 * np.asarray([0.25, 0.0625])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    expect = -0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(upd["w"]), expect, rtol=1e-4)


def test_adamw_weight_decay_decoupled():
    opt = adamw(weight_decay=0.1)
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.0])}
    state = opt.init(p)
    upd, _ = opt.update(g, state, p, lr=0.5)
    # zero grad -> pure decay: -lr * wd * p
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.5 * 0.1 * 2.0], rtol=1e-6)


def test_adafactor_reduces_quadratic():
    opt = adafactor()
    w = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)), jnp.float32)
    p = {"w": w}
    state = opt.init(p)
    loss = lambda p_: jnp.sum(jnp.square(p_["w"]))
    for _ in range(30):
        g = jax.grad(loss)(p)
        upd, state = opt.update(g, state, p, lr=0.05)
        p = jax.tree_util.tree_map(lambda a, u: a + u, p, upd)
    assert float(loss(p)) < float(jnp.sum(jnp.square(w))) * 0.5


def test_adafactor_state_is_factored():
    opt = adafactor()
    p = {"w": jnp.zeros((16, 32)), "b": jnp.zeros((32,))}
    st = opt.init(p)
    assert st["f"]["w"]["vr"].shape == (16,)
    assert st["f"]["w"]["vc"].shape == (32,)
    assert st["f"]["b"]["v"].shape == (32,)


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_shapes():
    s = cosine_with_warmup(1e-3, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(s(100)) == pytest.approx(1e-4, rel=1e-2)


def test_grad_accum_equivalence():
    """accum=2 must produce the same update as accum=1 (mean-of-grads)."""
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg)
    opt = adamw()
    sched = lambda step: 1e-3
    batch = make_concrete_batch(cfg, smoke_shape("train"))
    s1, _ = init_state(model, jax.random.PRNGKey(0), opt)
    s2, _ = init_state(model, jax.random.PRNGKey(0), opt)
    step1 = jax.jit(make_train_step(model, opt, sched, grad_accum=1))
    step2 = jax.jit(make_train_step(model, opt, sched, grad_accum=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(
        jax.tree_util.tree_leaves(s1.params), jax.tree_util.tree_leaves(s2.params)
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-4, atol=5e-5,
        )


def test_loss_decreases_over_steps():
    cfg = smoke_variant(get_config("granite-3-2b"))
    model = build_model(cfg)
    opt = adamw()
    step = jax.jit(make_train_step(model, opt, cosine_with_warmup(3e-3, 2, 50),
                                   grad_accum=1))
    state, _ = init_state(model, jax.random.PRNGKey(0), opt)
    batch = make_concrete_batch(cfg, smoke_shape("train"))
    first = last = None
    for _ in range(6):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first
