"""Overlap + link-contention regression gates.

Back-compat contract of the contention-aware DES (ISSUE 9): pricing with a
fitted :class:`repro.netprof.model.LinkContentionModel` must be a strict
extension — timelines whose priced link intervals never overlap are
bit-identical to the classic serialized run, for every registered config.
Only genuinely concurrent link intervals may stretch (by gamma(k)), and a
degenerate c=0 model is normalized away entirely.

The executor-side twin of the same contract: bucketing the gradient
all-reduce (``Strategy.overlap_buckets``) repartitions the simulated
``gradAR`` nodes without moving a byte — wire and raw totals are exact
across every config — and T011 polices the sim side (a timeline with T010
overlap priced without an available contention model).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.timeline_checks import audit_timeline
from repro.configs.base import get_config, list_archs
from repro.core.autotuner import layer_cost_from_config
from repro.core.estimator import OpTimeEstimator, dist_comm_bytes
from repro.core.graph import DataflowGraph
from repro.core.hardware import TPU_V5E
from repro.core.simulator import simulate
from repro.core.strategy import Strategy, pipeline_graph
from repro.netprof.model import LinkContentionModel

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CM = LinkContentionModel(platform="test", c=0.5, samples=3)


def _events(res):
    return [
        (e.node, e.name, e.device, e.start, e.end) for e in res.events
    ]


def _sim_pair(graph, duration_fn, contention):
    """(serialized, contended) runs of one graph."""
    base = simulate(graph, duration_fn, record_events=True)
    cont = simulate(
        graph, duration_fn, record_events=True, contention=contention
    )
    return base, cont


def _assert_bit_equal(base, cont):
    assert cont.makespan == base.makespan
    assert cont.device_busy == base.device_busy
    assert cont.time_by_kind == base.time_by_kind
    assert _events(cont) == _events(base)


# -- zero-overlap back-compat: every registered config ------------------------


@pytest.mark.parametrize("arch", list_archs())
def test_zero_overlap_contention_bitparity(arch):
    """dp-only plans put every collective on ONE link stream — intervals
    never overlap, so the contention-aware run must be bit-identical."""
    cfg = get_config(arch)
    cost = layer_cost_from_config(cfg, 1, 256, 1)
    strat = Strategy(dp=4, compression="int8")
    g = pipeline_graph(cfg.num_layers, cost, strat)
    est = OpTimeEstimator(TPU_V5E)
    base, cont = _sim_pair(g, est.duration, CM)
    assert base.contention is None
    assert cont.contention is not None  # model attached, just never engaged
    _assert_bit_equal(base, cont)


@pytest.mark.parametrize("arch", list_archs())
def test_c_zero_model_is_exact_legacy_path(arch):
    """A degenerate c=0 model is normalized away even on overlapping
    pipeline plans: gamma(k)=1 means exact serialized arithmetic."""
    cfg = get_config(arch)
    pp = 2 if cfg.num_layers % 2 == 0 else 1
    cost = layer_cost_from_config(cfg, 1, 256, 1)
    strat = Strategy(dp=4, pp=pp, microbatches=max(pp, 2) if pp > 1 else 1)
    g = pipeline_graph(cfg.num_layers, cost, strat)
    est = OpTimeEstimator(TPU_V5E)
    zero = LinkContentionModel(platform="test", c=0.0, samples=1)
    base, cont = _sim_pair(g, est.duration, zero)
    assert cont.contention is None  # normalized to the legacy path
    _assert_bit_equal(base, cont)


# -- contention semantics ------------------------------------------------------


def test_contention_stretches_only_overlap():
    g = DataflowGraph()
    g.add("a", "all-reduce", device="link:dp0")
    g.add("b", "all-reduce", device="link:dp1")
    dur = lambda n: 1.0
    base, cont = _sim_pair(g, dur, CM)
    assert base.makespan == 1.0  # free overlap, classic DES
    # both 1.0s jobs fully shared: each runs at rate 1/gamma(2) = 1/1.5
    assert cont.makespan == pytest.approx(1.5)
    full = simulate(
        g, dur, record_events=True,
        contention=LinkContentionModel(platform="t", c=1.0, samples=1),
    )
    assert full.makespan == pytest.approx(2.0)  # c=1 == full serialization


def test_same_link_fifo_unchanged():
    g = DataflowGraph()
    g.add("a", "all-reduce", device="link:dp0")
    g.add("b", "all-reduce", device="link:dp0")
    base, cont = _sim_pair(g, lambda n: 1.0, CM)
    assert base.makespan == cont.makespan == 2.0
    _assert_bit_equal(base, cont)


# -- T011: silent serialized pricing -------------------------------------------


def test_t011_fires_only_when_model_available_and_unapplied():
    g = DataflowGraph()
    g.add("a", "all-reduce", device="link:dp0")
    g.add("b", "all-reduce", device="link:dp1")
    dur = lambda n: 1.0
    serialized = simulate(g, dur, record_events=True)
    contended = simulate(g, dur, record_events=True, contention=CM)

    fired = audit_timeline(serialized, g, contention_available=True)
    assert [d.code for d in fired.warnings] == ["T011"]
    quiet_no_model = audit_timeline(serialized, g, contention_available=False)
    assert "T011" not in quiet_no_model.codes()
    quiet_applied = audit_timeline(contended, g, contention_available=True)
    assert "T011" not in quiet_applied.codes()


def test_analyzer_applies_available_contention_model():
    from repro.analysis.analyzer import analyze_training_plan
    from repro.core.database import ProfileDB
    from repro.netprof.sweep import (
        synthetic_calibration, synthetic_contention_calibration,
    )

    db = ProfileDB()
    synthetic_calibration(db, "tpu_v5e")
    synthetic_contention_calibration(db, "tpu_v5e", c=0.4)
    est = OpTimeEstimator(TPU_V5E, db)
    assert est.contention_model is not None
    cfg = get_config("llama3.2-1b")
    strat = Strategy(dp=4, pp=2, microbatches=4, compression="int8",
                     overlap_buckets=4)
    rep = analyze_training_plan(
        cfg, strat, micro_batch=1, seq=256, estimator=est
    )
    assert rep.ok, rep.summary_lines()
    assert "T011" not in rep.codes()
    assert rep.metrics.get("sim_contention_applied") == 1.0
    # same plan, no estimator: no model available, T011 must stay quiet
    rep2 = analyze_training_plan(cfg, strat, micro_batch=1, seq=256)
    assert "T011" not in rep2.codes()
    assert "sim_contention_applied" not in rep2.metrics


# -- bucketed gradAR: exact byte repartition -----------------------------------


@pytest.mark.parametrize("arch", list_archs())
def test_gradar_bucket_byte_partition(arch):
    cfg = get_config(arch)
    if cfg.num_layers % 4 != 0:
        pytest.skip("needs layers divisible by pp*vstages=4")
    # bucketing repartitions a stage's backward CHUNKS, so the stage needs
    # >= 2 of them: interleaved vstages=2 gives every stage two
    cost = layer_cost_from_config(cfg, 1, 256, 1)
    mk = lambda ob: pipeline_graph(
        cfg.num_layers, cost,
        Strategy(dp=4, pp=2, vstages=2, schedule="interleaved_1f1b",
                 microbatches=4, compression="int8", overlap_buckets=ob),
    )
    g0, g4 = mk(0), mk(4)
    ar0 = [n for n in g0.nodes if n.name.startswith("gradAR")]
    ar4 = [n for n in g4.nodes if n.name.startswith("gradAR")]
    assert len(ar4) > len(ar0)
    assert sum(n.comm_bytes for n in ar4) == pytest.approx(
        sum(n.comm_bytes for n in ar0), rel=0, abs=0
    )
    assert sum(dist_comm_bytes(n) for n in ar4) == pytest.approx(
        sum(dist_comm_bytes(n) for n in ar0)
    )
    # every bucket node sits on its stage's dp link (same-link FIFO: the
    # win is the earlier launch, never a new wire)
    assert {n.device for n in ar4} == {n.device for n in ar0}
    # buckets launch earlier: the first bucket depends on strictly fewer
    # backward chunks than the monolithic node
    deps4 = min(len(n.deps) for n in ar4)
    deps0 = min(len(n.deps) for n in ar0)
    assert deps4 < deps0


def test_bucketed_graph_overlap_speedup():
    """The tentpole's measurable win: with a contention-priced DES, the
    bucketed plan's earlier launches beat the monolithic all-reduce."""
    cfg = get_config("llama3.2-1b")
    cost = layer_cost_from_config(cfg, 1, 256, 1)
    mk = lambda ob: pipeline_graph(
        cfg.num_layers, cost,
        Strategy(dp=4, pp=2, vstages=2, schedule="interleaved_1f1b",
                 microbatches=4, compression="int8", overlap_buckets=ob),
    )
    est = OpTimeEstimator(TPU_V5E)
    mono = simulate(mk(0), est.duration, contention=CM)
    bucketed = simulate(mk(4), est.duration, contention=CM)
    assert bucketed.makespan < mono.makespan


# -- executor twin: bucketed psum bit-parity on real devices -------------------

_BUCKET_PSUM_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import AxisType, shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist.compress import (
        bucketed_pmean, compressed_psum, init_feedback_state,
    )

    DP = 4
    mesh = jax.make_mesh((DP,), ("data",), axis_types=(AxisType.Auto,))
    rng = np.random.default_rng(0)
    tree = {
        "a": jnp.asarray(rng.standard_normal((DP, 8, 16)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((DP, 33)), jnp.float32),
        "c": jnp.asarray(rng.standard_normal((DP, 2, 3, 5)), jnp.float32),
    }
    state = init_feedback_state(
        {k: v[0] for k, v in tree.items()}, DP
    )

    def run(fn):
        wrapped = shard_map(
            fn, mesh=mesh,
            in_specs=(jax.tree_util.tree_map(lambda _: P("data"), tree),),
            out_specs=P(),
            check_vma=False,
        )
        return jax.jit(wrapped)(tree)

    for buckets in (0, 2, 3):
        got = run(functools.partial(
            bucketed_pmean, axis_name="data", buckets=buckets))
        if buckets == 0:
            ref = got
        else:
            for k in ref:
                np.testing.assert_array_equal(
                    np.asarray(ref[k]), np.asarray(got[k]))

    def comp(grads, buckets):
        local = {k: v[0] for k, v in state.items()}
        means, _ = compressed_psum(grads, "data", local, buckets=buckets)
        return means

    for buckets in (0, 2, 3):
        got = run(functools.partial(comp, buckets=buckets))
        if buckets == 0:
            ref = got
        else:
            for k in ref:
                np.testing.assert_array_equal(
                    np.asarray(ref[k]), np.asarray(got[k]))
    print("bucketed_psum_parity_ok")
    """
)


@pytest.mark.slow
def test_bucketed_psum_bitparity_4_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _BUCKET_PSUM_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "bucketed_psum_parity_ok" in out.stdout


# -- RunSpec ------------------------------------------------------------------


def test_runspec_roundtrip_and_flags():
    import argparse

    from repro.launch import spec as runspec

    s = runspec.RunSpec(compression="int8", overlap_buckets=4,
                        overlap_comm=True, pp=2, microbatches=4)
    assert runspec.RunSpec.from_dict(s.to_dict()) == s
    # defaults are elided from the serialized form
    assert "slots" not in s.to_dict()
    strat = s.strategy(dp=4)
    assert strat.overlap_buckets == 4 and strat.compression == "int8"
    assert strat.pp == 2

    ap = argparse.ArgumentParser()
    runspec.add_args(ap, "model", "train")
    args = ap.parse_args(
        ["--compression", "int8", "--overlap-buckets", "4",
         "--overlap-comm", "--pp", "2", "--microbatches", "4"]
    )
    assert runspec.from_args(args) == s

    class R:
        extras: dict = {}

    r = R()
    r.extras = {}
    runspec.attach(r, s)
    assert r.extras["run_spec"] == s.to_dict()
