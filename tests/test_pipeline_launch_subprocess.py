"""Slow tier: the real model through real multi-stage pipeline meshes.

Two subprocesses (forced 4 host devices):

  * the launcher itself — ``launch/train.py --pp 4 --pp-schedule
    interleaved_1f1b`` on the real (smoke-reduced) llama transformer:
    loss must decrease and the printed comm report's simulator bytes must
    equal the executor byte twin;
  * gradient parity on real stage meshes — the pipeline-partitioned
    transformer's scheduled backward vs ``jax.grad`` of the GSPMD
    reference on pp=4 (gpipe/1f1b) and pp=2 interleaved meshes, plus a
    dp2 x pp2 int8-compressed pipeline train step.
"""
import os
import re
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(args_or_script, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    if isinstance(args_or_script, str):
        cmd = [sys.executable, "-c", args_or_script]
    else:
        cmd = [sys.executable] + args_or_script
    return subprocess.run(
        cmd, env=env, capture_output=True, text=True, timeout=timeout,
    )


@pytest.mark.slow
def test_launch_train_pp4_interleaved_real_model():
    out = _run([
        "-m", "repro.launch.train",
        "--arch", "llama3.2-1b", "--smoke", "--layers", "8",
        "--d-model", "64", "--steps", "8", "--seq", "32", "--batch", "8",
        "--pp", "4", "--pp-schedule", "interleaved_1f1b",
        "--vstages", "2", "--microbatches", "4",
    ])
    assert out.returncode == 0, out.stderr[-3000:]
    # the launcher executed the pipeline plan (not the GSPMD mesh)...
    assert "[pp-exec] executing" in out.stdout, out.stdout
    # ...with simulator comm bytes equal to the executor byte twin
    m = re.search(r"sim=(\d+) exec=(\d+) \(parity ok\)", out.stdout)
    assert m, out.stdout
    assert m.group(1) == m.group(2)
    # and the loss decreased over the run
    m = re.search(r"\[done\] .*loss ([0-9.]+) -> ([0-9.]+)", out.stdout)
    assert m, out.stdout
    assert float(m.group(2)) < float(m.group(1)), out.stdout


_PARITY_SCRIPT = textwrap.dedent(
    """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ShapeConfig, get_config, smoke_variant
    from repro.models import build_model
    from repro.models.build import make_concrete_batch
    from repro.models.pipeline import (
        make_plan, microbatched_reference, pipeline_loss_and_grads,
    )

    cfg = smoke_variant(get_config("llama3.2-1b"))
    cfg = dataclasses.replace(
        cfg, num_layers=8, d_model=64, num_heads=2, num_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=256,
    )
    shape = ShapeConfig("t", 16, 4, "train")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, shape)
    mesh4 = jax.make_mesh((4,), ("stage",),
                          axis_types=(jax.sharding.AxisType.Auto,))
    mesh2 = jax.make_mesh((2,), ("stage",),
                          axis_types=(jax.sharding.AxisType.Auto,))

    for name, S, M, v, mesh in (
        ("gpipe", 4, 4, 1, mesh4),
        ("1f1b", 4, 4, 1, mesh4),
        ("interleaved_1f1b", 2, 2, 2, mesh2),
    ):
        plan = make_plan(cfg, S, M, schedule=name, vstages=v)
        loss, metrics, grads = jax.jit(
            lambda p, b, plan=plan, mesh=mesh: pipeline_loss_and_grads(
                plan, p, b, mesh
            )
        )(params, batch)
        ref = microbatched_reference(model, M)
        rl, rg = jax.value_and_grad(ref)(params, batch)
        assert abs(float(loss) - float(rl)) < 1e-4 * abs(float(rl))
        flat_ref = dict(jax.tree_util.tree_leaves_with_path(rg))
        for kp, g in jax.tree_util.tree_leaves_with_path(grads):
            r = flat_ref[kp]
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(r), rtol=5e-4,
                atol=5e-4 * float(jnp.max(jnp.abs(r)) + 1e-8),
                err_msg=f"{name} {kp}",
            )
        print(f"model_pp_grads_ok:{name}")

    # dp2 x pp2 int8-compressed pipeline training step
    from repro.optim import adamw, cosine_with_warmup
    from repro.train.step import init_state, make_pipeline_train_step

    mesh22 = jax.make_mesh((2, 2), ("data", "stage"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    shape2 = ShapeConfig("t2", 16, 8, "train")
    batch2 = make_concrete_batch(cfg, shape2)
    plan = make_plan(cfg, 2, 2, schedule="1f1b")
    opt = adamw()
    step = jax.jit(make_pipeline_train_step(
        model, opt, cosine_with_warmup(1e-3, 2, 100), mesh22, plan,
        compression="int8",
    ))
    state, _ = init_state(
        model, jax.random.PRNGKey(0), opt, compression="int8", dp=2
    )
    losses = []
    for _ in range(6):
        state, metrics = step(state, batch2)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    print("model_pp_dp_int8_ok")
    """
)


@pytest.mark.slow
def test_real_mesh_model_pipeline_grad_parity():
    out = _run(_PARITY_SCRIPT)
    assert out.returncode == 0, out.stderr[-3000:]
    for marker in (
        "model_pp_grads_ok:gpipe",
        "model_pp_grads_ok:1f1b",
        "model_pp_grads_ok:interleaved_1f1b",
        "model_pp_dp_int8_ok",
    ):
        assert marker in out.stdout, (marker, out.stdout, out.stderr[-1500:])
