"""Roofline extraction, timeline export, report generation."""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, get_config
from repro.core import (
    TPU_V5E,
    build_report,
    model_flops,
    module_summary,
    simulate,
    to_chrome_trace,
)
from repro.core.estimator import OpTimeEstimator
from repro.core.roofline import to_row


def _small_summary():
    def f(x, w):
        return jnp.tanh(x @ w).sum()

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    return module_summary(jax.jit(f).lower(xs, ws).compile().as_text())


def test_roofline_terms_positive_and_dominant():
    cfg = get_config("llama3.2-1b")
    rep = build_report(cfg, SHAPES["train_4k"], "single", 256, _small_summary())
    assert rep.compute_s > 0 and rep.memory_s > 0
    assert rep.dominant in ("compute", "memory", "collective")
    assert rep.bound_time_s == max(
        rep.compute_s, rep.memory_s, rep.collective_s
    )
    row = to_row(rep)
    assert set(row) >= {"arch", "shape", "dominant", "useful_flop_ratio"}


def test_model_flops_kinds():
    cfg = get_config("llama3.2-1b")
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.active_params()
    assert t == pytest.approx(6 * n * 256 * 4096)
    assert p == pytest.approx(2 * n * 32 * 32768)
    assert d == pytest.approx(2 * n * 128)


def test_moe_active_params_smaller_than_total():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.active_params() < 0.06 * cfg.num_params()  # ~32B of 1T


def test_chrome_trace_export(tmp_path):
    s = _small_summary()
    est = OpTimeEstimator(TPU_V5E)
    res = simulate(s["graph"], est.duration, record_events=True)
    path = os.path.join(tmp_path, "trace.json")
    trace = to_chrome_trace(res, path)
    raw = json.load(open(path))
    events = [e for e in raw["traceEvents"] if e.get("ph") == "X"]
    assert events, "no duration events exported"
    assert all(e["dur"] >= 0 for e in events)
    names = {e["args"]["name"] for e in raw["traceEvents"] if e.get("ph") == "M"}
    assert "chip" in names


def test_report_generator_runs_on_sweep_data():
    from benchmarks.roofline_report import dryrun_table, load, roofline_table

    recs = load()
    if not recs:
        pytest.skip("no sweep data present")
    t1 = dryrun_table(recs)
    t2 = roofline_table(recs, "single")
    assert t1.count("|") > 50 and "arch" in t1
    assert "dominant" not in t2 or "compute" in t2 or "memory" in t2


def test_dot_meta_recovered():
    def f(a, b):
        return a @ b

    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    s = module_summary(jax.jit(f).lower(xs, ws).compile().as_text())
    dots = [n for n in s["graph"].nodes if n.kind == "dot"]
    assert dots and dots[0].meta.get("dot")
    d = dots[0].meta["dot"]
    assert d["lhs"] == [32, 64] and d["rhs"] == [64, 16]
    assert d["lc"] == [1] and d["rc"] == [0]


def test_estimator_overhead_and_clamp():
    from repro.core.database import ProfileDB, ProfileEntry
    from repro.core.graph import OpNode
    from repro.core.hardware import CPU_HOST

    db = ProfileDB()
    db.meta("cpu_host")["op_overhead_s"] = 1e-6
    # enough points to fit a vector model with a wild law
    for i in range(2, 22):
        db.add("cpu_host", "add",
               ProfileEntry({"size": 2**i}, 1e-3, 0.0, n=3,
                            flops=2.0**i, bytes=2.0**i * 8))
    est = OpTimeEstimator(CPU_HOST, db)
    # zero-flop giant copy: clamp must keep it near the analytic roofline
    node = OpNode(0, "c", "copy", flops=0.0, in_bytes=1e9, out_bytes=1e9)
    t = est.duration(node)
    analytic = 2e9 / CPU_HOST.chip.hbm_bw
    assert t <= 50 * analytic + 1e-3
    assert t >= 0.25 * analytic
