"""Sim <-> real parity: one step table drives the DES and the executor.

The acceptance contract for the schedule subsystem: for every schedule the
simulated DataflowGraph and the shard_map executor's accounting twin agree
on (1) total comm bytes, (2) bubble counts, and (3) per-device event
ordering — and the executor's explicit scheduled backward reproduces
autodiff gradients bit-for-bit in structure (allclose in float).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimator import OpTimeEstimator, dist_comm_bytes
from repro.core.simulator import simulate
from repro.core.strategy import LayerCost, Strategy, pipeline_graph
from repro.dist import pp
from repro.dist.schedules import build_executor_plan, make_schedule

CASES = [
    ("gpipe", 4, 8, 1),
    ("1f1b", 4, 8, 1),
    ("1f1b", 2, 6, 1),
    ("interleaved_1f1b", 2, 4, 2),
    ("interleaved_1f1b", 4, 8, 2),
]


def unit_dur(node):
    return 1.0 if node.kind in ("fwd", "bwd") else 0.0


@pytest.mark.parametrize("name,S,M,v", CASES)
def test_comm_byte_parity(name, S, M, v):
    """Graph comm volume == schedule twin == executor-plan twin, and the
    estimator's dist hook prices each hop with the same payload."""
    B, D = 2, 8
    hop = pp.boundary_bytes((B, D), jnp.float32)
    strategy = Strategy(pp=S, microbatches=M, schedule=name, vstages=v)
    cost = LayerCost(fwd_flops=1e6, fwd_bytes=1e4, boundary_bytes=hop)
    g = pipeline_graph(S * v, cost, strategy)

    sends = [n for n in g.nodes if n.kind == "collective-permute"]
    sim_total = sum(dist_comm_bytes(n) for n in sends)
    assert all(n.comm_bytes == hop for n in sends)
    assert all(n.meta["transfer"] == "pp_boundary" for n in sends)

    sch = make_schedule(name, S, M, v)
    plan = build_executor_plan(sch)
    assert sim_total == sch.comm_bytes(hop)
    assert sim_total == plan.comm_bytes(hop)
    assert sim_total == pp.schedule_transfer_bytes(sch, (B, D), jnp.float32)
    if v == 1:
        # the scheduled table generalizes PR 1's wavefront accounting
        assert sim_total == pp.pipeline_transfer_bytes(
            S, M, (B, D), jnp.float32, backward=True
        )


@pytest.mark.parametrize("name,S,M,v", CASES)
def test_bubble_count_parity(name, S, M, v):
    """DES per-device idle ticks == schedule.bubble_ticks for every stage."""
    strategy = Strategy(pp=S, microbatches=M, schedule=name, vstages=v)
    cost = LayerCost(fwd_flops=1.0, fwd_bytes=0.0, bwd_multiplier=1.0)
    g = pipeline_graph(S * v, cost, strategy)
    res = simulate(g, unit_dur)
    sch = make_schedule(name, S, M, v)
    assert res.makespan == pytest.approx(sch.total_ticks())
    for s in range(S):
        des_bubble = res.makespan - res.device_busy[f"stage{s}"]
        assert des_bubble == pytest.approx(sch.bubble_ticks(s)), s


@pytest.mark.parametrize("name,S,M,v", CASES)
def test_event_order_parity(name, S, M, v):
    """The DES executes each device's nodes in exactly the table order the
    shard_map executor runs."""
    strategy = Strategy(pp=S, microbatches=M, schedule=name, vstages=v)
    cost = LayerCost(fwd_flops=1.0, fwd_bytes=0.0, bwd_multiplier=1.0,
                     boundary_bytes=16.0)
    g = pipeline_graph(S * v, cost, strategy)
    res = simulate(g, unit_dur, record_events=True)
    sch = make_schedule(name, S, M, v)
    for s in range(S):
        sim_order = [
            e.name for e in sorted(res.events, key=lambda e: (e.start, e.node))
            if e.device == f"stage{s}"
        ]
        table_order = [step.name for step in sch.stage_steps(s)]
        assert sim_order == table_order, f"stage {s}"


@pytest.mark.parametrize("name,v", [("gpipe", 1), ("1f1b", 1),
                                    ("interleaved_1f1b", 2)])
def test_executor_matches_autodiff_reference(name, v, rng):
    """The scheduled explicit backward == jax.grad of the sequential model
    (single-stage mesh; real multi-stage runs in the slow subprocess tier)."""
    L, M, B, D = 4, 2, 2, 8
    w = jnp.asarray(rng.standard_normal((L, D, D)), jnp.float32) * 0.2
    xs = jnp.asarray(rng.standard_normal((M, B, D)), jnp.float32)
    layer_fn = lambda p, x: jnp.tanh(x @ p["w"])  # noqa: E731
    mesh = jax.make_mesh((1,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sch = make_schedule(name, 1, M, v)

    def seq_loss(w_):
        def s(x):
            for i in range(L):
                x = jnp.tanh(x @ w_[i])
            return x
        ys = jax.vmap(s)(xs)
        return 0.5 * jnp.sum(ys * ys)

    loss, outs, grads = jax.jit(
        lambda p, x: pp.pipeline_schedule_shard_map(p, x, layer_fn, mesh, sch)
    )({"w": w}, xs)
    np.testing.assert_allclose(float(loss), float(seq_loss(w)), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(grads["w"]), np.asarray(jax.grad(seq_loss)(w)),
        rtol=1e-4, atol=1e-5,
    )
    # outputs agree with the forward-only wavefront executor too
    wave = pp.pipeline_step_shard_map({"w": w}, xs, layer_fn, mesh)
    np.testing.assert_allclose(np.asarray(outs), np.asarray(wave),
                               rtol=1e-5, atol=1e-6)


def test_param_arrangement_roundtrip(rng):
    """Device-major layout and its inverse are exact inverses, and rows land
    on the devices the schedule places them on."""
    sch = make_schedule("interleaved_1f1b", 4, 8, 2)
    L, D = 16, 4
    w = jnp.asarray(rng.standard_normal((L, D)), jnp.float32)
    arranged = pp.arrange_params_for_schedule({"w": w}, sch)["w"]
    assert arranged.shape == (8, 2, D)  # (S*v, L/(S*v), D)
    back = pp.unarrange_params_for_schedule({"w": arranged}, sch)["w"]
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))
    per_chunk = L // sch.n_vstages
    for s in range(sch.n_stages):
        for c in range(sch.vstages):
            k = sch.vstage_of(s, c)
            np.testing.assert_array_equal(
                np.asarray(arranged[s * sch.vstages + c]),
                np.asarray(w[k * per_chunk:(k + 1) * per_chunk]),
            )


def test_strategy_builds_schedule_and_autotuner_enumerates():
    """Strategy(schedule=interleaved_1f1b) resolves to the shared table and
    the autotuner searches over it."""
    from repro.configs.base import get_config
    from repro.core.autotuner import Autotuner

    st = Strategy(pp=4, microbatches=8, schedule="interleaved_1f1b", vstages=2)
    sch = st.make_pipeline_schedule()
    assert sch.name == "interleaved_1f1b" and sch.vstages == 2
    assert "interleaved_1f1bv2" in st.describe()

    tuner = Autotuner(get_config("llama3.2-1b"), chips=16, global_batch=64,
                      seq=512)
    cands = tuner.candidates(microbatch_options=(4, 8))
    inter = [s for s in cands if s.schedule == "interleaved_1f1b"]
    assert inter, "autotuner must enumerate interleaved_1f1b"
    assert all(s.vstages > 1 and s.microbatches % s.pp == 0 for s in inter)
    r = tuner.evaluate(inter[0])
    assert r.makespan_s > 0

    # interleaving beats flat 1f1b at equal strategy when comm is cheap:
    # compare simulated bubbles on a comm-light cost profile
    flat = Strategy(pp=4, microbatches=8, schedule="1f1b")
    cost = LayerCost(fwd_flops=1e9, fwd_bytes=1e6, boundary_bytes=1e3)
    g_flat = pipeline_graph(16, cost, flat)
    g_int = pipeline_graph(16, cost, st)
    est = OpTimeEstimator(tuner.platform)
    m_flat = simulate(g_flat, est.duration).makespan
    m_int = simulate(g_int, est.duration).makespan
    assert m_int < m_flat


def test_interleaved_executor_loss_invariant_to_stage_count(rng):
    """Same model, same schedule family, S=1 vs S=1 v=2 vs gpipe: identical
    loss — the table changes the order, never the math."""
    L, M, B, D = 4, 2, 2, 4
    w = jnp.asarray(rng.standard_normal((L, D, D)), jnp.float32) * 0.3
    xs = jnp.asarray(rng.standard_normal((M, B, D)), jnp.float32)
    layer_fn = lambda p, x: jnp.tanh(x @ p["w"])  # noqa: E731
    mesh = jax.make_mesh((1,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    losses = []
    for name, v in [("gpipe", 1), ("interleaved_1f1b", 2)]:
        sch = make_schedule(name, 1, M, v)
        loss, _, _ = jax.jit(
            lambda p, x: pp.pipeline_schedule_shard_map(
                p, x, layer_fn, mesh, sch
            )
        )({"w": w}, xs)
        losses.append(float(loss))
    assert losses[0] == pytest.approx(losses[1], rel=1e-6)
