"""Schedule-table invariants: closure, memory windows, bubbles, bounds."""
import pytest

from repro.core.graph import OpNode
from repro.core.simulator import simulate
from repro.core.strategy import LayerCost, Strategy, pipeline_graph
from repro.dist.schedules import (
    FWD,
    GPipeSchedule,
    OneFOneBSchedule,
    Step,
    build_executor_plan,
    make_schedule,
)

GRID = [
    ("gpipe", 2, 2, 1), ("gpipe", 4, 8, 1), ("gpipe", 8, 3, 1),
    ("1f1b", 2, 2, 1), ("1f1b", 4, 8, 1), ("1f1b", 8, 3, 1),
    ("1f1b", 1, 4, 1),
    ("interleaved_1f1b", 2, 2, 2), ("interleaved_1f1b", 2, 4, 2),
    ("interleaved_1f1b", 4, 8, 2), ("interleaved_1f1b", 4, 8, 3),
    ("interleaved_1f1b", 1, 2, 2),
]


@pytest.mark.parametrize("name,S,M,v", GRID)
def test_tables_complete_and_dependency_closed(name, S, M, v):
    """Every (vstage, microbatch) fwd+bwd appears exactly once, and greedy
    per-device execution of the table never deadlocks (validate() builds
    the tick table, which requires each step's data deps to be produced by
    strictly earlier steps)."""
    sch = make_schedule(name, S, M, v)
    sch.validate()
    ticks = sch.tick_table()
    assert len(ticks) == 2 * S * v * M
    # dependency closure, stated directly: dep tick strictly precedes
    for step, t in ticks.items():
        for d in sch.data_deps(step):
            assert ticks[d] < t, (step, d)


def test_broken_table_rejected():
    """A table whose order violates its own data deps must not validate."""

    class Broken(OneFOneBSchedule):
        def stage_steps(self, stage):
            steps = super().stage_steps(stage)
            if stage == self.n_stages - 1:
                # demand the first backward before its forward exists
                bad = [s for s in steps if s.phase != FWD][:1]
                rest = [s for s in steps if s not in bad]
                return bad + rest
            return steps

    with pytest.raises(ValueError, match="deadlock"):
        Broken(4, 4).validate()


def test_incomplete_table_rejected():
    class Dropped(GPipeSchedule):
        def stage_steps(self, stage):
            return super().stage_steps(stage)[:-1]

    with pytest.raises(ValueError, match="incomplete"):
        Dropped(2, 3).validate()


def test_schedule_constructor_guards():
    with pytest.raises(ValueError):
        make_schedule("gpipe", 4, 8, vstages=2)
    with pytest.raises(ValueError):
        make_schedule("1f1b", 4, 8, vstages=2)
    with pytest.raises(ValueError, match="divisible"):
        make_schedule("interleaved_1f1b", 4, 6, vstages=2)  # M % S != 0
    with pytest.raises(ValueError, match="unknown"):
        make_schedule("zigzag", 2, 2)


@pytest.mark.parametrize("S,M", [(2, 4), (4, 8), (4, 16), (8, 8)])
def test_1f1b_in_flight_bound(S, M):
    """Classic 1F1B memory window: stage s never holds more than S - s
    live forward activations."""
    sch = make_schedule("1f1b", S, M)
    for s in range(S):
        assert sch.max_in_flight(s) <= S - s
    # ...and gpipe pays the full M window on every stage
    gp = make_schedule("gpipe", S, M)
    assert all(gp.max_in_flight(s) == M for s in range(S))


@pytest.mark.parametrize("S,M,v", [(2, 2, 2), (2, 4, 2), (4, 8, 2), (4, 8, 3)])
def test_interleaved_bubble_matches_analytic(S, M, v):
    """Interleaved-1F1B bubble = (S-1)/v * (t_fwd + t_bwd) in full-stage
    time units.  With unit per-chunk fwd/bwd ticks a full stage costs v
    ticks per phase, so the per-device idle time must be exactly
    2 * (S - 1) ticks = (S-1)/v * (v + v)."""
    sch = make_schedule("interleaved_1f1b", S, M, v)
    t_fwd_stage = t_bwd_stage = v  # one stage = v unit-tick chunks
    expect = (S - 1) * (t_fwd_stage + t_bwd_stage) // v
    for s in range(S):
        assert sch.bubble_ticks(s) == expect == sch.analytic_bubble_ticks()
    # total ticks: perfect overlap outside the bubble
    assert sch.total_ticks() == 2 * M * v + 2 * (S - 1)


def test_interleaving_shrinks_relative_bubble():
    """Same device work, v=2 halves the bubble's share of the makespan."""
    flat = make_schedule("1f1b", 4, 8)
    inter = make_schedule("interleaved_1f1b", 4, 8, 2)
    rel_flat = flat.bubble_ticks(0) / flat.total_ticks()
    rel_inter = inter.bubble_ticks(0) / inter.total_ticks()
    assert rel_inter < rel_flat
    # the price: v times the boundary hops
    assert inter.comm_steps() == (4 * 2 - 1) * 8
    assert flat.comm_steps() == (4 - 1) * 8


@pytest.mark.parametrize("name,S,M,v", GRID)
def test_makespan_respects_critical_path_lower_bound(name, S, M, v):
    """graph.py's longest-path bound holds for every schedule's DAG."""
    strategy = Strategy(pp=S, microbatches=M, schedule=name, vstages=v)
    cost = LayerCost(fwd_flops=1.0, fwd_bytes=0.0, bwd_multiplier=2.0,
                     boundary_bytes=64.0)
    g = pipeline_graph(S * v, cost, strategy)

    def dur(node: OpNode) -> float:
        return {"fwd": 1.0, "bwd": 2.0}.get(node.kind, 0.5)

    lower = g.critical_path(dur)
    res = simulate(g, dur)
    assert lower <= res.makespan + 1e-9
    # serialization edges make the bound tight for the last device's chain
    assert res.makespan >= 3.0 * M  # stage work alone


def test_tick_table_matches_unit_time_des():
    """total_ticks is the DES makespan at tf=tb=1 with free comm — the two
    accounting paths are the same schedule."""
    for name, S, M, v in GRID:
        sch = make_schedule(name, S, M, v)
        g = pipeline_graph(
            S * v,
            LayerCost(fwd_flops=1.0, fwd_bytes=0.0, bwd_multiplier=1.0),
            Strategy(pp=S, microbatches=M, schedule=name, vstages=v),
        )
        res = simulate(
            g, lambda n: 1.0 if n.kind in ("fwd", "bwd") else 0.0
        )
        assert res.makespan == pytest.approx(sch.total_ticks()), (name, S, M, v)


def test_executor_plan_consistency():
    for name, S, M, v in GRID:
        sch = make_schedule(name, S, M, v)
        plan = build_executor_plan(sch)
        assert plan.n_ticks == sch.total_ticks()
        # every scheduled hop appears once per direction
        assert plan.comm_steps() == sch.comm_steps() == (S * v - 1) * M
        # receives are claimed exactly once per (device, chunk, microbatch)
        for valid, chunks, mbs in (
            (plan.recv_fwd_valid, plan.recv_fwd_chunk, plan.recv_fwd_mb),
            (plan.recv_bwd_valid, plan.recv_bwd_chunk, plan.recv_bwd_mb),
        ):
            seen = set()
            for t in range(plan.n_ticks):
                for s in range(S):
                    if valid[t][s]:
                        key = (s, chunks[t][s], mbs[t][s])
                        assert key not in seen
                        seen.add(key)


def test_step_table_is_tick_ordered():
    sch = make_schedule("interleaved_1f1b", 4, 8, 2)
    ticks = sch.tick_table()
    order = sch.steps()
    assert [s.key for s in order] == sorted(
        (s.key for s in order),
        key=lambda k: (ticks[Step(k[1] % 4, k[1], k[2], k[0])],
                       (k[1] % 4)),
    )
    assert len(order) == len({s.key for s in order})
