"""Serve-plan sanitizer (R codes) + ProfileDB coverage auditor (A005+).

Two halves of ``repro.analysis``'s pre-run serving gate:

* ``serve_checks`` — the acceptance trace must verify clean, and a corpus
  of tampered :class:`ServePlan`s must trigger every R code with the
  offending request id and step index named;
* ``coverage`` — the classification of every statically-enumerated
  pricing query (exact / interpolation / extrapolation / fallback) must
  match the ``time_provenance`` stamps the pricer actually produces when
  the same plan is priced.
"""
import dataclasses
import json
import os

import pytest

from repro.analysis import PlanVerificationError
from repro.analysis.coverage import (
    CLASS_EXACT,
    CLASS_FALLBACK,
    CLASS_INTERP,
    CLASS_TO_PROVENANCE,
    audit_collective_coverage,
    audit_serve_coverage,
    classify_collective_query,
    classify_serve_query,
    enumerate_serve_queries,
)
from repro.analysis.serve_checks import (
    AdmitRecord,
    FreeRecord,
    ServePlan,
    audit_serve_plan,
    check_serve_plan,
    extract_serve_plan,
    lint_serve_trace,
)
from repro.core.database import ProfileDB, ProfileEntry
from repro.serve.cost import ServePricer, synthetic_serve_calibration
from repro.serve.policy import ServeConfig
from repro.serve.trace import TraceRequest, load_trace

ARCH = "llama3.2-1b"
TRACE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks", "traces", "serve_acceptance.json",
)


def _scfg(**kw) -> ServeConfig:
    base = dict(slots=2, max_len=64, block_size=8, chunk=8)
    base.update(kw)
    return ServeConfig(**base)


def _trace():
    return load_trace(TRACE_PATH)


def _plan() -> ServePlan:
    return extract_serve_plan(_trace(), _scfg())


def _db(slot_grid=(1, 2, 4), buckets=(1, 2, 4, 8, 16, 32), arch=ARCH):
    db = ProfileDB()
    scfg = _scfg()
    synthetic_serve_calibration(
        db, arch, "cpu_host", views=(scfg.view_len,),
        buckets=buckets, slot_grid=slot_grid,
    )
    return db


# ---------------------------------------------------------------------------
# the committed acceptance trace verifies clean
# ---------------------------------------------------------------------------

def test_acceptance_trace_plan_is_clean():
    report = audit_serve_plan(_trace(), _scfg())
    assert report.ok, report.codes()
    assert report.metrics["serve_plan_requests"] == 16
    assert report.metrics["serve_plan_steps"] > 0
    assert 0 < report.metrics["serve_peak_pool_utilization"] <= 1.0
    assert report.metrics["serve_tokens_total"] > 16   # >= 1 token each


def test_serve_plan_json_roundtrip(tmp_path):
    plan = _plan()
    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = ServePlan.load(path)
    assert loaded.to_dict() == plan.to_dict()
    assert check_serve_plan(loaded).ok


def test_trace_lint_rejects_oversized_and_duplicate_requests():
    scfg = _scfg()
    trace = [
        TraceRequest(rid=0, arrival_s=0.0, prompt_len=65, max_new_tokens=4),
        TraceRequest(rid=0, arrival_s=0.0, prompt_len=8, max_new_tokens=4),
    ]
    report = lint_serve_trace(trace, scfg)
    assert "R004" in report.codes()     # prompt beyond max_len
    assert "R005" in report.codes()     # duplicate rid
    # a footprint that can never fit the pool is caught pre-extraction
    tiny = _scfg(num_blocks=3)
    report = lint_serve_trace(
        [TraceRequest(rid=1, arrival_s=0.0, prompt_len=60,
                      max_new_tokens=4)],
        tiny,
    )
    assert "R003" in report.codes()


# ---------------------------------------------------------------------------
# tampered-plan corpus: every R code fires, naming rid and step
# ---------------------------------------------------------------------------

def _replace_step(plan: ServePlan, i: int, **kw) -> ServePlan:
    steps = list(plan.steps)
    steps[i] = dataclasses.replace(steps[i], **kw)
    return dataclasses.replace(plan, steps=steps)


def _tamper_r001_leak(plan):
    for i in range(len(plan.steps) - 1, -1, -1):
        if plan.steps[i].freed:
            return _replace_step(plan, i, freed=())
    raise AssertionError("no frees in plan")


def _tamper_r002_double_free(plan):
    for i, s in enumerate(plan.steps):
        if s.freed:
            return _replace_step(plan, i, freed=s.freed + (s.freed[0],))
    raise AssertionError("no frees in plan")


def _tamper_r003_out_of_pool(plan):
    for i, s in enumerate(plan.steps):
        if s.admitted:
            adm = s.admitted[0]
            bad = dataclasses.replace(
                adm, blocks=(plan.num_blocks + 7,) + adm.blocks[1:]
            )
            return _replace_step(plan, i, admitted=(bad,) + s.admitted[1:])
    raise AssertionError("no admissions in plan")


def _tamper_r004_budget_above_cap(plan):
    for i, s in enumerate(plan.steps):
        if s.admitted:
            adm = s.admitted[0]
            bad = dataclasses.replace(adm, budget=adm.budget + 50)
            return _replace_step(plan, i, admitted=(bad,) + s.admitted[1:])
    raise AssertionError("no admissions in plan")


def _tamper_r005_admit_before_arrival(plan):
    arrivals = {int(r["rid"]): float(r["arrival_s"]) for r in plan.requests}
    for i, s in enumerate(plan.steps):
        for adm in s.admitted:
            if arrivals[adm.rid] > 0:
                return _replace_step(
                    plan, i, clock_s=arrivals[adm.rid] - 1.0
                )
    raise AssertionError("every request arrives at t=0")


def _tamper_r006_duplicate_decode_slot(plan):
    for i, s in enumerate(plan.steps):
        if s.decode_slots:
            dup = s.decode_slots + (s.decode_slots[0],)
            return _replace_step(plan, i, decode_slots=dup)
    raise AssertionError("no decode steps in plan")


def _tamper_r007_prefill_outside_prompt(plan):
    for i, s in enumerate(plan.steps):
        if s.prefill is not None:
            slot, rid, start, width, final = s.prefill
            return _replace_step(
                plan, i, prefill=(slot, rid, start, width + 100, final)
            )
    raise AssertionError("no prefill steps in plan")


_TAMPERS = {
    "R001": _tamper_r001_leak,
    "R002": _tamper_r002_double_free,
    "R003": _tamper_r003_out_of_pool,
    "R004": _tamper_r004_budget_above_cap,
    "R005": _tamper_r005_admit_before_arrival,
    "R006": _tamper_r006_duplicate_decode_slot,
    "R007": _tamper_r007_prefill_outside_prompt,
}


@pytest.mark.parametrize("code", sorted(_TAMPERS))
def test_tampered_plan_triggers_each_r_code(code):
    report = check_serve_plan(_TAMPERS[code](_plan()), name=f"tamper:{code}")
    assert not report.ok
    assert code in report.codes(), report.codes()
    # every finding of the seeded code names a request and a step (the
    # end-of-plan leak names the rid; in-step findings also carry `step`)
    for d in report.by_code(code):
        assert "rid" in d.where or "slot" in d.where, d.where


def test_corpus_covers_every_r_code():
    seeded = set()
    for code, tamper in _TAMPERS.items():
        seeded |= {
            c for c in check_serve_plan(tamper(_plan())).codes()
            if c.startswith("R")
        }
    assert seeded >= {f"R00{i}" for i in range(1, 8)}


def test_untampered_plans_never_fire(seed_range=range(3)):
    # regression guard for the sanitizer itself: real scheduler output is
    # clean under varied serving shapes
    for slots, chunk in ((1, 4), (2, 8), (4, 16)):
        scfg = _scfg(slots=slots, chunk=chunk)
        report = check_serve_plan(extract_serve_plan(_trace(), scfg))
        assert report.ok, (slots, chunk, report.codes())


# ---------------------------------------------------------------------------
# dynamic error paths mirror the static codes
# ---------------------------------------------------------------------------

def test_allocator_errors_name_request_and_code():
    from repro.serve.blocks import BlockAllocator, OutOfBlocksError

    alloc = BlockAllocator(num_blocks=4, block_size=8)
    with pytest.raises(OutOfBlocksError, match=r"request 7.*R003"):
        alloc.alloc(5, owner=7)
    got = alloc.alloc(2, owner=7)
    alloc.free(got, owner=7)
    with pytest.raises(ValueError, match=r"request 7.*R002"):
        alloc.free(got, owner=7)


# ---------------------------------------------------------------------------
# coverage auditor: classification
# ---------------------------------------------------------------------------

def test_coverage_full_grid_all_exact():
    cov = audit_serve_coverage(_trace(), ARCH, _scfg(), _db())
    assert cov.report.ok
    assert cov.report.metrics["coverage_exact"] == (
        cov.report.metrics["coverage_queries"]
    )
    assert cov.families["serve_prefill"]["exact_ratio"] == 1.0
    assert cov.families["serve_decode"]["exact_ratio"] == 1.0
    assert cov.grid == [] and cov.commands == []
    assert cov.report.codes() == []


def test_coverage_gapped_grid_interpolates():
    # decode batch (slots=2) sits between the measured 1 and 4
    cov = audit_serve_coverage(_trace(), ARCH, _scfg(), _db(slot_grid=(1, 4)))
    assert cov.report.ok                      # info + warnings, no errors
    assert {"A007", "A008", "A009"} <= set(cov.report.codes())
    assert cov.report.metrics["coverage_interpolation"] == 1
    assert cov.families["serve_decode"]["exact_ratio"] == 0.0
    assert cov.families["serve_prefill"]["exact_ratio"] == 1.0
    (entry,) = cov.grid
    assert entry["family"] == "serve_decode"
    assert entry["args"]["slots"] == 2
    (cmd,) = cov.commands
    assert "repro.launch.serve" in cmd and "--calibrate" in cmd


def test_coverage_sparse_buckets_extrapolate():
    # prompts need buckets {4, 8}; only {1, 2} are measured -> beyond grid
    cov = audit_serve_coverage(
        _trace(), ARCH, _scfg(), _db(buckets=(1, 2), slot_grid=(1, 2, 4))
    )
    assert cov.report.ok
    assert "A006" in cov.report.codes()
    assert cov.report.metrics["coverage_extrapolation"] >= 2
    assert cov.families["serve_prefill"]["exact_ratio"] == 0.0


def test_coverage_unmeasured_arch_is_an_error_a005():
    cov = audit_serve_coverage(
        _trace(), ARCH, _scfg(), _db(arch="mamba2-2.7b")
    )
    assert not cov.report.ok
    assert "A005" in cov.report.codes()
    assert cov.report.metrics["coverage_fallback"] == (
        cov.report.metrics["coverage_queries"]
    )
    with pytest.raises(PlanVerificationError):
        cov.report.raise_on_errors()


def test_calibration_grid_closes_the_gaps():
    scfg = _scfg()
    db = _db(slot_grid=(1, 4), buckets=(1, 2))
    first = audit_serve_coverage(_trace(), ARCH, scfg, db)
    assert first.grid
    # "measure" exactly the emitted grid, nothing else
    for entry in first.grid:
        db.add(
            "cpu_host", entry["family"],
            ProfileEntry(args=dict(entry["args"]), mean_s=1e-3, std_s=0.0,
                         n=1, flops=0.0, bytes=0.0),
        )
    second = audit_serve_coverage(_trace(), ARCH, scfg, db)
    assert second.report.metrics["coverage_exact"] == (
        second.report.metrics["coverage_queries"]
    )
    assert second.grid == []


def test_enumeration_is_timing_independent():
    # the query set depends only on (trace, scfg) arithmetic — the same
    # queries fall out of any per-step cost the scheduler might see
    queries = enumerate_serve_queries(_trace(), ARCH, _scfg())
    families = {q.family for q in queries}
    assert families == {"serve_prefill", "serve_decode"}
    buckets = sorted(
        q.args_dict["tokens"] for q in queries
        if q.family == "serve_prefill"
    )
    assert buckets == [4, 8]          # prompts 8..24 in chunk-8 strides
    (dec,) = [q for q in queries if q.family == "serve_decode"]
    assert dec.args_dict["slots"] == 2
    assert dec.count > 0              # total decode-token upper bound


# ---------------------------------------------------------------------------
# classification vs the provenance the pricer actually stamps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "db_builder",
    [
        lambda: _db(),                                  # all exact
        lambda: _db(slot_grid=(1, 4)),                  # decode interpolates
        lambda: _db(buckets=(1, 2), slot_grid=(1, 4)),  # prefill extrapolates
        lambda: _db(arch="mamba2-2.7b"),                # everything falls back
    ],
    ids=["exact", "interp", "extrap", "fallback"],
)
def test_serve_classification_matches_stamped_provenance(db_builder):
    from repro.configs.base import get_config, smoke_variant
    from repro.core.estimator import OpTimeEstimator
    from repro.core.hardware import CPU_HOST
    from repro.serve.cost import _XKEY
    from repro.serve.sim import simulate_serve

    db = db_builder()
    scfg = _scfg()
    cfg = smoke_variant(get_config(ARCH))
    est = OpTimeEstimator(CPU_HOST, db=db, use_learned=False)
    res = simulate_serve(_trace(), cfg, scfg, est)
    pricer = ServePricer(db, "cpu_host")

    queries = {
        (q.family, q.args_dict[_XKEY[q.family]]):
            classify_serve_query(pricer, q)
        for q in enumerate_serve_queries(_trace(), cfg.name, scfg)
    }
    checked = 0
    for node in res.graph.nodes:
        serve = node.meta.get("serve")
        if serve is None:
            continue
        cls = queries[(serve["family"], serve[_XKEY[serve["family"]]])]
        assert node.meta["time_provenance"] in CLASS_TO_PROVENANCE[cls], (
            node.name, cls, node.meta["time_provenance"]
        )
        checked += 1
    assert checked == len(res.graph.nodes) > 0


def test_collective_classification_matches_priced_provenance():
    from repro.core.graph import DataflowGraph
    from repro.core.hardware import TPU_V5E
    from repro.netprof.pricing import CollectivePricer
    from repro.netprof.sweep import synthetic_calibration

    db = ProfileDB()
    synthetic_calibration(
        db, TPU_V5E.name, groups=(2, 4),
        payload_bytes=(4096, 65536), collectives=("all-reduce",),
    )
    pricer = CollectivePricer(db, TPU_V5E)
    link = TPU_V5E.link_for("ici")

    g = DataflowGraph("cov")
    cases = [
        ("exact", "all-reduce", 4096.0, 4, CLASS_EXACT),
        ("interp", "all-reduce", 16000.0, 4, CLASS_INTERP),
        ("extrap", "all-reduce", 2.0 ** 30, 4, "extrapolation"),
        ("fallback", "all-gather", 4096.0, 4, CLASS_FALLBACK),
    ]
    for name, kind, b, grp, _ in cases:
        g.add(name, kind, link_kind="ici", group_size=grp, comm_bytes=b)

    cov = audit_collective_coverage(g, pricer, db_path="db.json")
    by_class = {(q["family"], q["args"]["per_device_bytes"]): q["class"]
                for q in cov.queries}
    for _, kind, b, grp, expect in cases:
        cls = by_class[(kind, int(round(b)))]
        assert cls == expect, (kind, b, cls)
        t, prov = pricer.price(kind, b, grp, link)
        assert prov in CLASS_TO_PROVENANCE[cls], (kind, b, cls, prov)
    assert "A005" in cov.report.codes()       # the all-gather fallback
    assert any("calibrate_net.py" in c for c in cov.commands)


# ---------------------------------------------------------------------------
# wiring: analyzer entry points and the launcher gate
# ---------------------------------------------------------------------------

def test_analyze_serve_trace_attaches_coverage_document():
    from repro.analysis import analyze_serve_trace

    report = analyze_serve_trace(_trace(), ARCH, _scfg(), db=_db())
    assert report.ok
    doc = report.extras["coverage"][ARCH]
    assert set(doc) == {
        "name", "ok", "queries", "families", "calibration_grid", "commands"
    }
    assert doc["ok"] and doc["queries"]
    rendered = json.loads(report.to_json())
    assert rendered["extras"]["coverage"][ARCH]["families"]


def test_analyze_serve_sweep_acceptance_clean_for_every_arch():
    from repro.analysis import analyze_serve_sweep
    from repro.configs.base import list_archs

    merged = analyze_serve_sweep(_trace())
    assert merged.ok, merged.codes()
    assert merged.metrics["serve_plans_analyzed"] == len(list_archs())
    # the sweep's synthetic grids cover the acceptance trace exactly
    assert merged.metrics["coverage_exact"] == (
        merged.metrics["coverage_queries"]
    )


def test_launch_serve_analyze_gate(tmp_path, monkeypatch):
    from repro.launch import serve as launch_serve

    def run(*argv):
        monkeypatch.setattr(
            "sys.argv", ["python -m repro.launch.serve", *argv]
        )
        return launch_serve.main()

    # the committed acceptance trace passes the static gate
    assert run(
        "--arch", ARCH, "--smoke", "--slots", "2", "--max-len", "64",
        "--block-size", "8", "--chunk", "8",
        "--trace-file", TRACE_PATH, "--analyze", "--synthetic-db",
    ) == 0

    # a tampered serialized plan is rejected before any device work
    good = str(tmp_path / "good.json")
    bad = str(tmp_path / "bad.json")
    _plan().save(good)
    _tamper_r002_double_free(_plan()).save(bad)
    assert run("--analyze-plan", good) == 0
    with pytest.raises(PlanVerificationError) as ei:
        run("--analyze-plan", bad)
    assert "R002" in str(ei.value)
