"""Property tests: KV block allocator, block tables, cache splicing.

Runs under real hypothesis or ``repro.testing.hypothesis_fallback``
(installed by conftest.py when hypothesis is absent).
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.blocks import (
    BlockAllocator,
    BlockTable,
    OutOfBlocksError,
    blocks_for_tokens,
)
from repro.serve.engine import splice_cache

NUM_BLOCKS = 24


def test_blocks_for_tokens_ceil():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(-3, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2
    assert blocks_for_tokens(64, 16) == 4


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 7), st.integers(1, 6), st.booleans()),
        min_size=1, max_size=40,
    )
)
def test_allocator_never_shares_and_returns_blocks(ops):
    """Invariants under arbitrary alloc/free interleavings: a block never
    belongs to two live owners, free accounting is exact, and freeing an
    owner returns every one of its blocks to the pool."""
    a = BlockAllocator(NUM_BLOCKS, block_size=8)
    owned: dict[str, set[int]] = {}
    for owner_i, n, do_free in ops:
        owner = f"r{owner_i}"
        if do_free and owner in owned:
            a.free_owner(owner)
            owned.pop(owner)
        elif a.can_alloc(n):
            blocks = a.alloc(n, owner)
            assert len(blocks) == len(set(blocks)) == n
            in_use = set().union(*owned.values()) if owned else set()
            assert not set(blocks) & in_use, "block handed to two owners"
            owned.setdefault(owner, set()).update(blocks)
        else:
            with pytest.raises(OutOfBlocksError):
                a.alloc(n, owner)
        assert a.num_free == NUM_BLOCKS - sum(len(s) for s in owned.values())
        for o, s in owned.items():
            assert set(a.blocks_of(o)) == s
    for owner in list(owned):
        a.free_owner(owner)
    assert a.num_free == NUM_BLOCKS


def test_allocator_rejects_foreign_free():
    a = BlockAllocator(4, block_size=8)
    (b,) = a.alloc(1, "r0")
    with pytest.raises(ValueError):
        a.free([b + 1])
    a.free([b])
    assert a.num_free == 4


def test_allocator_deterministic_lowest_first():
    a = BlockAllocator(8, block_size=8)
    assert a.alloc(3, "r0") == [0, 1, 2]
    a.free_owner("r0")
    b = BlockAllocator(8, block_size=8)
    assert b.alloc(3, "x") == [0, 1, 2]


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 6))
def test_block_table_locate(block_size, nblocks):
    blocks = [10 + 3 * i for i in range(nblocks)]
    t = BlockTable(blocks, block_size)
    assert t.capacity == nblocks * block_size
    for pos in range(t.capacity):
        bid, off = t.locate(pos)
        assert bid == blocks[pos // block_size]
        assert off == pos % block_size
    with pytest.raises(IndexError):
        t.locate(t.capacity)
    with pytest.raises(IndexError):
        t.locate(-1)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(0, 4), st.integers(0, 10_000))
def test_splice_cache_pytree_roundtrip(slots, slot, seed):
    """splice_cache writes sequence-0 of the single-slot tree into exactly
    slot ``slot`` of the full tree, for arbitrary pytrees whose leaves put
    the batch axis at different positions."""
    slot = slot % slots
    rng = np.random.default_rng(seed)
    full = {
        "k": rng.standard_normal((slots, 4, 3)).astype(np.float32),
        "nested": [
            rng.standard_normal((3, slots, 2)).astype(np.float32),
            rng.standard_normal((slots,)).astype(np.float32),
        ],
    }
    one = {
        "k": rng.standard_normal((1, 4, 3)).astype(np.float32),
        "nested": [
            rng.standard_normal((3, 1, 2)).astype(np.float32),
            rng.standard_normal((1,)).astype(np.float32),
        ],
    }
    out = jax.tree_util.tree_map(np.asarray, splice_cache(full, one, slot))

    def check(f, o, g, axis):
        sel = [slice(None)] * f.ndim
        sel[axis] = slot
        np.testing.assert_array_equal(g[tuple(sel)], np.take(o, 0, axis))
        untouched = [s for s in range(slots) if s != slot]
        sel[axis] = untouched
        exp = [slice(None)] * f.ndim
        exp[axis] = untouched
        np.testing.assert_array_equal(g[tuple(sel)], f[tuple(exp)])

    check(full["k"], one["k"], out["k"], axis=0)
    check(full["nested"][0], one["nested"][0], out["nested"][0], axis=1)
    check(full["nested"][1], one["nested"][1], out["nested"][1], axis=0)
