"""Continuous-batching engine vs the sequential decode reference.

The paged engine (chunked prefill, scatter/gather KV blocks, batched
decode with scratch lanes) must be *token-for-token* identical to the
plain ``Model.prefill`` + ``decode_step`` greedy loop: ``_sdpa_dense``
masks with ``finfo(f32).min``, so masked pool positions contribute exactly
0.0 to the softmax and the padded gathered view computes the same numbers
as the reference's contiguous cache.

Also pins the seed engine's ``slot_len`` off-by-one: capacity is now
exactly ``max_len`` cached positions (``max_len - prompt_len + 1`` output
tokens), where the old engine clamped at ``max_len - 1`` and re-wrote the
final cache position.

The slow tier adds a subprocess test on a forced-8-device host: the
slot-sharded engine must produce the same tokens as the unsharded one.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.models import build_model

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _tiny(name: str, **kw):
    """2-layer smoke config: same code path, minimal jit time."""
    return dataclasses.replace(smoke_variant(get_config(name)),
                               num_layers=2, **kw)


def _reference_greedy(model, params, prompt, n_tokens, max_len):
    """Sequential whole-prompt prefill + one-token decode_step loop."""
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, {"tokens": t}, max_len)
    )(params, jnp.asarray(np.asarray(prompt)[None, :]))
    toks = [int(jnp.argmax(logits[0, -1]))]
    clen = len(prompt)
    dec = jax.jit(model.decode)
    for _ in range(n_tokens - 1):
        lg, cache = dec(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32), clen
        )
        toks.append(int(jnp.argmax(lg[0, -1])))
        clen += 1
    return toks


def _build(name, seed=0, **kw):
    cfg = _tiny(name, **kw)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def test_engine_chunked_prefill_matches_reference_dense(rng):
    """Prompts longer than the chunk (incl. a non-pow2 final chunk) and two
    interleaved slots still match the sequential reference exactly."""
    from repro.serve import Request, ServeEngine

    cfg, model, params = _build("llama3.2-1b")
    prompts = [
        rng.integers(1, cfg.vocab_size, 21, dtype=np.int32),  # 8+8+5 chunks
        rng.integers(1, cfg.vocab_size, 9, dtype=np.int32),   # 8+1
    ]
    eng = ServeEngine(model, params, slots=2, max_len=48,
                      block_size=8, chunk=8)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    done = {r.rid: r.output for r in eng.run_until_done()}
    for rid, p in enumerate(prompts):
        assert done[rid] == _reference_greedy(model, params, p, 5, 48), (
            f"request {rid} diverged from the sequential reference"
        )


def test_engine_matches_reference_moe(rng):
    from repro.serve import Request, ServeEngine

    cfg, model, params = _build("qwen3-moe-235b-a22b", seed=1)
    prompt = rng.integers(1, cfg.vocab_size, 12, dtype=np.int32)
    eng = ServeEngine(model, params, slots=2, max_len=32,
                      block_size=8, chunk=8)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    done = eng.run_until_done()
    assert done[0].output == _reference_greedy(model, params, prompt, 4, 32)


def test_engine_boundary_runs_to_exactly_max_len(rng):
    """Off-by-one regression: a request may fill ALL max_len cache
    positions.  prompt 20 + budget 64 in a 32-position cache must emit
    exactly 32 - 20 + 1 = 13 tokens, all matching the reference (the seed
    engine clamped at max_len - 1, re-writing the last position)."""
    from repro.serve import Request, ServeEngine

    cfg, model, params = _build("llama3.2-1b")
    prompt = rng.integers(1, cfg.vocab_size, 20, dtype=np.int32)
    eng = ServeEngine(model, params, slots=1, max_len=32,
                      block_size=8, chunk=8)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=64))
    done = eng.run_until_done()
    assert len(done[0].output) == 13
    assert done[0].output == _reference_greedy(model, params, prompt, 13, 32)
    # every block (incl. the final one) was written and returned
    assert eng.sched.allocator.num_free == eng.sched.allocator.num_blocks - 1
    assert eng.sched.allocator.blocks_of("__scratch__") == [0]


def test_engine_eos_early_exit(rng):
    from repro.serve import Request, ServeEngine

    cfg, model, params = _build("llama3.2-1b")
    prompt = rng.integers(1, cfg.vocab_size, 8, dtype=np.int32)

    eng = ServeEngine(model, params, slots=1, max_len=32, block_size=8)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    free_run = eng.run_until_done()[0].output
    assert len(free_run) == 8

    eos = free_run[2]
    eng2 = ServeEngine(model, params, slots=1, max_len=32, block_size=8,
                       eos_id=eos)
    eng2.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    got = eng2.run_until_done()[0].output
    assert got == free_run[: got.index(eos) + 1]
    assert eos in got


def test_engine_single_slot_queueing_isolated(rng):
    """Three requests through one slot: sequential occupancy, FIFO order,
    and no KV state leaking between consecutive occupants of the slot."""
    from repro.serve import Request, ServeEngine

    cfg, model, params = _build("llama3.2-1b")
    prompts = [rng.integers(1, cfg.vocab_size, 6 + 3 * r, dtype=np.int32)
               for r in range(3)]
    eng = ServeEngine(model, params, slots=1, max_len=32,
                      block_size=8, chunk=8)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=3))
    done = {r.rid: r for r in eng.run_until_done()}
    for rid, p in enumerate(prompts):
        assert done[rid].output == _reference_greedy(model, params, p, 3, 32)
    # FIFO completion and latency records populated
    e2es = [done[r].e2e_s for r in range(3)]
    assert e2es == sorted(e2es)
    for r in range(3):
        assert done[r].ttft_s is not None
        assert len(done[r].token_times_s) == 3


_SHARD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.compat import make_mesh
    from repro.configs.base import get_config, smoke_variant
    from repro.models import build_model
    from repro.serve import Request, ServeEngine

    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 6 + r, dtype=np.int32)
               for r in range(8)]

    def run(mesh):
        eng = ServeEngine(model, params, slots=8, max_len=32,
                          block_size=8, chunk=8, mesh=mesh)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=4))
        return {r.rid: r.output for r in eng.run_until_done()}

    assert jax.device_count() == 8, jax.device_count()
    plain = run(None)
    sharded = run(make_mesh((8,), ("serve",)))
    assert plain == sharded, (plain, sharded)
    print("shard_parity_ok")
    """
)


@pytest.mark.slow
def test_engine_sharded_8dev_matches_unsharded():
    """Slot-sharded decode on a forced-8-device host is token-identical to
    the single-device engine (the decode batch is data-parallel over
    slots; sharding must not change the math)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "shard_parity_ok" in out.stdout
