"""DES serving twin: determinism, engine parity, pricing provenance.

Three layers of guarantees, in rising strength:

* the priced simulation is *deterministic* — same trace + same synthetic
  DB give a bit-identical latency report, in-process and across Python
  processes with different hash seeds (the check.sh determinism gate);
* the scheduler twin replaying the engine's measured step durations
  reproduces the engine's step compositions AND its latency records
  *exactly* (shared-policy parity, the hard gate);
* every priced serve node carries ``time_provenance`` (A004 audit) and
  the provenance chain is DB -> fit -> analytic with no ring fallback.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.core.database import ProfileDB
from repro.core.estimator import OpTimeEstimator
from repro.core.hardware import CPU_HOST
from repro.serve.cost import synthetic_serve_calibration
from repro.serve.policy import ServeConfig
from repro.serve.report import (
    latency_report,
    percentile,
    records_from_requests,
    serve_parity_report,
)
from repro.serve.sim import replay_schedule, simulate_serve
from repro.serve.trace import (
    TraceRequest,
    bursty_trace,
    load_trace,
    poisson_trace,
    prompt_tokens,
    save_trace,
)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_SMOKE = smoke_variant(get_config("llama3.2-1b"))


def _synthetic_setup(scfg, *, slot_grid=(1, 2, 4), arch=None):
    db = ProfileDB()
    synthetic_serve_calibration(
        db, arch or _SMOKE.name, "cpu_host",
        views=(scfg.view_len,), slot_grid=slot_grid,
    )
    return OpTimeEstimator(CPU_HOST, db=db, use_learned=False), db


# -- report primitives ---------------------------------------------------------


def test_percentile_nearest_rank():
    assert percentile([], 50) == 0.0
    assert percentile([5.0], 99) == 5.0
    assert percentile([4.0, 1.0, 3.0, 2.0], 50) == 2.0
    assert percentile([4.0, 1.0, 3.0, 2.0], 100) == 4.0
    vals = [float(i) for i in range(1, 101)]
    assert percentile(vals, 99) == 99.0
    assert percentile(vals, 1) == 1.0


def test_parity_report_detects_divergence():
    a = [(0, (), None, (0,)), (1, (), None, (0,))]
    ok = serve_parity_report(a, list(a))
    assert ok["composition_ok"] and ok["ok"]

    diverged = serve_parity_report(a, [a[0], (1, (), None, (0, 1))])
    assert not diverged["composition_ok"] and not diverged["ok"]
    assert diverged["composition_mismatches"][0]["step"] == 1

    short = serve_parity_report(a, a[:1])
    assert not short["composition_ok"]

    lat = {"per_token_p50_s": 1.0, "per_token_p99_s": 1.0, "ttft_p50_s": 1.0}
    sim = dict(lat, per_token_p99_s=2.0)  # 100% error
    bad = serve_parity_report(a, list(a), engine_latency=lat,
                              sim_latency=sim, tol_rel=0.5)
    assert bad["composition_ok"] and not bad["latency_ok"] and not bad["ok"]


# -- trace generators ----------------------------------------------------------


def test_trace_generators_deterministic_and_roundtrip(tmp_path):
    t1 = poisson_trace(10, 50.0, seed=7)
    t2 = poisson_trace(10, 50.0, seed=7)
    assert t1 == t2
    assert t1 != poisson_trace(10, 50.0, seed=8)
    arrivals = [r.arrival_s for r in t1]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0.0

    b = bursty_trace(3, 4, 0.25, seed=1)
    assert [r.arrival_s for r in b] == [0.25 * (i // 4) for i in range(12)]

    path = str(tmp_path / "trace.json")
    save_trace(path, t1)
    assert load_trace(path) == t1

    toks = prompt_tokens(t1[3], _SMOKE.vocab_size)
    np.testing.assert_array_equal(toks, prompt_tokens(t1[3], _SMOKE.vocab_size))
    assert len(toks) == t1[3].prompt_len
    assert toks.min() >= 1 and toks.max() < _SMOKE.vocab_size


# -- priced-sim determinism ----------------------------------------------------


def test_sim_deterministic_replay(tmp_path):
    """Same trace + same DB -> bit-identical latency report and step log,
    including through a save/load round trip of both trace and DB."""
    scfg = ServeConfig(slots=2, max_len=64, block_size=8, chunk=8)
    est, db = _synthetic_setup(scfg)
    trace = poisson_trace(6, 40.0, seed=3)

    r1 = simulate_serve(trace, _SMOKE, scfg, est)
    r2 = simulate_serve(trace, _SMOKE, scfg, est)
    assert r1.latency == r2.latency
    assert r1.step_log == r2.step_log
    assert r1.step_durations == r2.step_durations

    tpath, dpath = str(tmp_path / "t.json"), str(tmp_path / "db.json")
    save_trace(tpath, trace)
    db.save(dpath)
    est3 = OpTimeEstimator(CPU_HOST, db=ProfileDB.load_or_empty(dpath),
                           use_learned=False)
    r3 = simulate_serve(load_trace(tpath), _SMOKE, scfg, est3)
    assert r3.latency == r1.latency
    assert r3.step_log == r1.step_log

    # the JSON the CI gate compares round-trips exactly too
    assert json.loads(json.dumps(r1.latency)) == r1.latency


_DETERMINISM_SCRIPT = """
import json
from repro.configs.base import get_config, smoke_variant
from repro.core.database import ProfileDB
from repro.core.estimator import OpTimeEstimator
from repro.core.hardware import CPU_HOST
from repro.serve.cost import synthetic_serve_calibration
from repro.serve.policy import ServeConfig
from repro.serve.sim import simulate_serve
from repro.serve.trace import poisson_trace

cfg = smoke_variant(get_config("llama3.2-1b"))
scfg = ServeConfig(slots=2, max_len=64, block_size=8, chunk=8)
db = ProfileDB()
synthetic_serve_calibration(db, cfg.name, "cpu_host",
                            views=(scfg.view_len,), slot_grid=(1, 2, 4))
est = OpTimeEstimator(CPU_HOST, db=db, use_learned=False)
res = simulate_serve(poisson_trace(6, 40.0, seed=3), cfg, scfg, est)
print(json.dumps(res.latency, sort_keys=True))
print(json.dumps(res.step_log))
"""


def test_sim_deterministic_across_processes():
    """The priced serve report is bit-identical across Python processes
    with different hash seeds (scripts/check.sh determinism target)."""
    outs = []
    for hash_seed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hash_seed
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run(
            [sys.executable, "-c", _DETERMINISM_SCRIPT], env=env,
            capture_output=True, text=True, timeout=600,
        )
        assert out.returncode == 0, out.stderr[-3000:]
        outs.append(out.stdout)
    assert outs[0] == outs[1]


# -- scheduler behaviour through the twin --------------------------------------


def test_sim_caps_output_to_kv_capacity():
    """A huge token budget is capped to max_len - prompt_len + 1 cache
    positions (the boundary the engine's off-by-one fix pins)."""
    scfg = ServeConfig(slots=1, max_len=32, block_size=8, chunk=8)
    est, _ = _synthetic_setup(scfg, slot_grid=(1, 2))
    trace = [TraceRequest(rid=0, arrival_s=0.0, prompt_len=10,
                          max_new_tokens=500)]
    res = simulate_serve(trace, _SMOKE, scfg, est)
    assert res.records[0]["n_tokens"] == 32 - 10 + 1
    assert res.records[0]["e2e_s"] is not None


def test_sim_head_of_line_blocking_is_fifo():
    """A small request queued behind one that does not fit the block pool
    must NOT overtake it (reordering would break composition parity)."""
    # 4 blocks/slot; pool 7 = scratch + r0's 4 + 2 spare: r1 (needs 4)
    # blocks the queue head even though r2 (needs 1) would fit.
    scfg = ServeConfig(slots=2, max_len=32, block_size=8, chunk=8,
                       num_blocks=7)
    est, _ = _synthetic_setup(scfg, slot_grid=(1, 2))
    trace = [
        TraceRequest(rid=0, arrival_s=0.0, prompt_len=8, max_new_tokens=25),
        TraceRequest(rid=1, arrival_s=0.0, prompt_len=8, max_new_tokens=25),
        TraceRequest(rid=2, arrival_s=0.0, prompt_len=4, max_new_tokens=1),
    ]
    res = simulate_serve(trace, _SMOKE, scfg, est)
    first_tok = {r["rid"]: r["arrival_s"] + r["ttft_s"] for r in res.records}
    assert first_tok[0] < first_tok[1] <= first_tok[2]
    assert all(r["e2e_s"] is not None for r in res.records)


# -- engine <-> twin parity ----------------------------------------------------


class _Ticker:
    """Deterministic engine clock: 1ms per reading."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def test_engine_twin_composition_and_latency_parity(rng):
    """replay_schedule over the engine's measured durations reproduces the
    engine's step compositions AND latency records exactly — including
    timed arrivals that land mid-run (admission clock parity)."""
    import dataclasses

    import jax

    from repro.models import build_model
    from repro.serve import Request, ServeEngine

    cfg = dataclasses.replace(_SMOKE, num_layers=2)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    # ~1ms per step (deterministic fake clock) with arrivals interleaved
    # at 0 / 3.5ms / 7.2ms: request 1 and 2 arrive while 0 is in flight.
    trace = [
        TraceRequest(rid=0, arrival_s=0.0, prompt_len=9, max_new_tokens=6),
        TraceRequest(rid=1, arrival_s=3.5e-3, prompt_len=12,
                     max_new_tokens=4),
        TraceRequest(rid=2, arrival_s=7.2e-3, prompt_len=5, max_new_tokens=5),
    ]
    eng = ServeEngine(model, params, slots=2, max_len=32, block_size=8,
                      chunk=8, clock=_Ticker())
    for t in trace:
        eng.submit(Request(rid=t.rid, prompt=prompt_tokens(t, cfg.vocab_size),
                           max_new_tokens=t.max_new_tokens,
                           arrival_s=t.arrival_s))
    eng.run_until_done()

    twin = replay_schedule(trace, eng.serve_cfg, eng.step_durations)
    assert twin.step_log == eng.step_log
    assert twin.step_durations == eng.step_durations

    eng_records = records_from_requests(eng.finished)
    assert eng_records == twin.records
    makespan = max(t for r in eng.finished for t in r.token_times_s)
    assert latency_report(eng_records, makespan) == twin.latency

    report = serve_parity_report(eng.step_log, twin.step_log,
                                 engine_latency=latency_report(eng_records,
                                                               makespan),
                                 sim_latency=twin.latency, tol_rel=0.0)
    assert report["ok"], report


def test_replay_rejects_short_duration_list():
    trace = [TraceRequest(rid=0, arrival_s=0.0, prompt_len=4,
                          max_new_tokens=4)]
    scfg = ServeConfig(slots=1, max_len=16, block_size=8, chunk=8)
    with pytest.raises(RuntimeError, match="step counts diverge"):
        replay_schedule(trace, scfg, [1e-3])


def test_priced_replay_pins_compositions_to_measured_clock():
    """simulate_serve(step_durations=...) — the --obs join mode — must
    reproduce the measured-clock compositions exactly (same induction as
    replay_schedule) while the timeline still carries priced durations."""
    scfg = ServeConfig(slots=2, max_len=64, block_size=8, chunk=8)
    est, _ = _synthetic_setup(scfg)
    trace = poisson_trace(6, 40.0, seed=3)
    predictive = simulate_serve(trace, _SMOKE, scfg, est)
    # a measured clock 50x slower than the priced one shifts admissions,
    # so the predictive twin's compositions diverge — priced replay's don't
    measured = [50.0 * d for d in predictive.step_durations]
    replay = replay_schedule(trace, scfg, measured)
    priced = simulate_serve(trace, _SMOKE, scfg, est,
                            step_durations=measured)
    assert priced.step_log == replay.step_log
    assert priced.step_durations == replay.step_durations
    assert priced.step_durations == measured[:len(priced.step_durations)]
    assert priced.latency == replay.latency
    # the graph/timeline side is PRICED, not the measured durations
    names = {e.name for e in priced.timeline.events}
    assert names == {n.name for n in priced.graph.nodes}
    priced_total = sum(e.end - e.start for e in priced.timeline.events)
    assert 0.0 < priced_total < 0.5 * sum(measured)
    with pytest.raises(RuntimeError, match="step counts diverge"):
        simulate_serve(trace, _SMOKE, scfg, est, step_durations=measured[:2])


# -- provenance + audit --------------------------------------------------------


def test_sim_pricing_provenance_chain():
    """DB hit -> interpolated fit -> analytic roofline; never ring."""
    from repro.netprof.pricing import graph_provenance

    scfg = ServeConfig(slots=2, max_len=64, block_size=8, chunk=8)
    trace = poisson_trace(4, 40.0, seed=0)

    def provs(est):
        g = simulate_serve(trace, _SMOKE, scfg, est).graph
        # graph_provenance: {kind: {provenance: count}}
        by_kind = graph_provenance(g)
        assert set(by_kind) == {"serve_prefill", "serve_decode"}
        return {p for k in by_kind.values() for p in k}

    # decode batch (slots=2) and all pow2 prefill buckets on the grid
    est, _ = _synthetic_setup(scfg, slot_grid=(1, 2, 4))
    assert provs(est) == {"measured-db"}

    # decode x=2 off the grid -> log-log interpolated
    est, _ = _synthetic_setup(scfg, slot_grid=(1, 4))
    got = provs(est)
    assert "measured-fit" in got and "ring" not in got

    # arch absent from the DB entirely -> analytic roofline, not ring
    est, _ = _synthetic_setup(scfg, arch="some-other-arch")
    assert provs(est) == {"analytic"}


def test_audit_serve_timeline_a004():
    """Every priced serve node must carry time_provenance; a stripped node
    is an A004 error."""
    from repro.analysis import audit_serve_timeline

    scfg = ServeConfig(slots=2, max_len=64, block_size=8, chunk=8)
    est, _ = _synthetic_setup(scfg)
    res = simulate_serve(poisson_trace(4, 40.0, seed=0), _SMOKE, scfg, est)

    rep = audit_serve_timeline(res.timeline, res.graph)
    assert rep.ok, [f.message for f in rep.errors]
    assert rep.metrics["serve_nodes"] == len(res.graph.nodes)
    assert rep.metrics["serve_nodes"] > 0

    victim = next(n for n in res.graph.nodes if "serve" in n.meta)
    victim.meta.pop("time_provenance")
    rep2 = audit_serve_timeline(res.timeline, res.graph)
    assert not rep2.ok
    assert any(f.code == "A004" for f in rep2.errors)
