"""Sharding resolver: divisibility fallback, axis reuse, ZeRO, drops."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import make_ctx


def mesh1():
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def fake_ctx(sizes: dict, overrides=None):
    """ShardingCtx with a fake mesh exposing axis names/sizes."""
    import types

    ctx = make_ctx(mesh1(), overrides=overrides)

    class FakeMesh:
        axis_names = tuple(sizes)
        devices = types.SimpleNamespace(shape=tuple(sizes.values()))

    ctx.mesh = FakeMesh()
    return ctx


def test_divisible_shard():
    ctx = fake_ctx({"data": 16, "model": 16})
    spec = ctx.spec_for(("vocab", "embed"), (128256, 2048), "emb")
    assert spec == P("model", None)
    assert not ctx.drops


def test_non_divisible_drops_and_logs():
    ctx = fake_ctx({"data": 16, "model": 16})
    spec = ctx.spec_for(("vocab", "embed"), (49155, 2048), "emb")
    assert spec == P(None, None)
    assert len(ctx.drops) == 1
    assert "49155 % 16" in ctx.drops[0].reason


def test_heads_fallback_phi4():
    ctx = fake_ctx({"data": 16, "model": 16})
    spec = ctx.spec_for(("embed", "heads", "head_dim"), (3072, 24, 128), "wq")
    assert spec == P(None, None, None)  # 24 % 16 != 0 -> replicate heads


def test_batch_multi_axis():
    ctx = fake_ctx({"pod": 2, "data": 16, "model": 16})
    spec = ctx.spec_for(("batch", None), (256, 4096), "tokens")
    assert spec == P(("pod", "data"), None)


def test_batch_single_pod_fallback():
    ctx = fake_ctx({"data": 16, "model": 16})
    spec = ctx.spec_for(("batch", None), (256, 4096), "tokens")
    assert spec == P("data", None)


def test_axis_reuse_forbidden():
    ctx = fake_ctx({"data": 16, "model": 16})
    # both logical dims want 'model'; second must fall back
    spec = ctx.spec_for(("heads", "ffn"), (32, 8192), "weird")
    assert spec == P("model", None)


def test_rule_override():
    ctx = fake_ctx(
        {"data": 16, "model": 16},
        overrides={"kv_seq": (("data",), ())},
    )
    spec = ctx.spec_for(("batch", "kv_seq", "kv_heads", "head_dim"),
                        (1, 524288, 8, 128), "kcache")
    assert spec == P(None, "data", None, None)


def test_zero1_attaches_data_axis():
    ctx = fake_ctx({"data": 16, "model": 16})
    spec = ctx.zero_spec_for(("layers", "embed", "ffn"), (16, 2048, 8192), "wg")
    # ffn got model; ZeRO adds data to the largest remaining divisible dim
    flat = [a for p in spec if p for a in ((p,) if isinstance(p, str) else p)]
    assert "data" in flat and "model" in flat


def test_unknown_logical_axis_raises():
    ctx = fake_ctx({"data": 2})
    with pytest.raises(KeyError):
        ctx.spec_for(("nonexistent",), (8,), "x")
