"""Hypothesis property tests for the sharding resolver and hardware models."""
import types

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hardware import TPU_V5E, collective_time, wire_bytes
from repro.models.sharding import DEFAULT_RULES, make_ctx


def fake_ctx(sizes: dict, overrides=None):
    import jax

    ctx = make_ctx(
        jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2),
        overrides=overrides,
    )

    class FakeMesh:
        axis_names = tuple(sizes)
        devices = types.SimpleNamespace(shape=tuple(sizes.values()))

    ctx.mesh = FakeMesh()
    return ctx


LOGICALS = sorted(DEFAULT_RULES)


@settings(max_examples=150, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.one_of(st.none(), st.sampled_from(LOGICALS)),
            st.integers(1, 100_000),
        ),
        min_size=1, max_size=6,
    ),
    st.sampled_from([
        {"data": 16, "model": 16},
        {"pod": 2, "data": 16, "model": 16},
        {"data": 4, "model": 2},
        {"data": 1, "model": 1},
    ]),
)
def test_resolver_invariants(dims, mesh_sizes):
    """For ANY tensor: every sharded dim divides evenly; no mesh axis is
    used twice; unknown axes never appear."""
    ctx = fake_ctx(mesh_sizes)
    axes = tuple(a for a, _ in dims)
    shape = tuple(s for _, s in dims)
    spec = ctx.spec_for(axes, shape, "t")
    used = []
    for part, size in zip(tuple(spec), shape):
        if part is None:
            continue
        names = (part,) if isinstance(part, str) else part
        prod = 1
        for n in names:
            assert n in mesh_sizes
            used.append(n)
            prod *= mesh_sizes[n]
        assert size % prod == 0, (axes, shape, spec)
    assert len(used) == len(set(used)), f"axis reused: {spec}"


@settings(max_examples=100, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.one_of(st.none(), st.sampled_from(LOGICALS)),
            st.integers(1, 100_000),
        ),
        min_size=1, max_size=5,
    ),
)
def test_zero_spec_never_less_sharded(dims):
    """zero_spec_for shards at least as much as spec_for (it only adds)."""
    ctx = fake_ctx({"data": 16, "model": 16})
    axes = tuple(a for a, _ in dims)
    shape = tuple(s for _, s in dims)
    base = tuple(ctx.spec_for(axes, shape, "t"))
    ctx2 = fake_ctx({"data": 16, "model": 16})
    zero = tuple(ctx2.zero_spec_for(axes, shape, "t"))

    def nshards(spec):
        n = 1
        for p in spec:
            if p is None:
                continue
            for a in (p,) if isinstance(p, str) else p:
                n *= {"data": 16, "model": 16}[a]
        return n

    assert nshards(zero) >= nshards(base)
    # zero specs obey the same divisibility invariant
    for part, size in zip(zero, shape):
        if part is None:
            continue
        prod = 1
        for a in (part,) if isinstance(part, str) else part:
            prod *= {"data": 16, "model": 16}[a]
        assert size % prod == 0


# -- hardware models ------------------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(
    st.sampled_from(["all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute"]),
    st.floats(1.0, 1e12),
    st.integers(1, 512),
)
def test_collective_time_nonnegative_monotone_in_bytes(kind, nbytes, group):
    t1 = collective_time(kind, nbytes, group, TPU_V5E.ici)
    t2 = collective_time(kind, nbytes * 2, group, TPU_V5E.ici)
    assert t1 >= 0.0
    assert t2 >= t1
    if group == 1:
        assert t1 == 0.0


@settings(max_examples=100, deadline=None)
@given(st.floats(1.0, 1e12), st.integers(2, 512))
def test_allreduce_wire_bytes_bounds(nbytes, group):
    """Ring all-reduce moves < 2x the payload; all-gather < 1x."""
    ar = wire_bytes("all-reduce", nbytes, group)
    ag = wire_bytes("all-gather", nbytes, group)
    assert 0 < ar < 2 * nbytes
    assert 0 < ag < nbytes
    assert ar == pytest.approx(2 * ag)
