"""DES engine: exact schedules on known DAGs + hypothesis invariants."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import DataflowGraph
from repro.core.simulator import Simulator, simulate


def unit_duration(node):
    return node.meta.get("dur", 1.0)


def make_chain(durs):
    g = DataflowGraph("chain")
    prev = []
    for i, d in enumerate(durs):
        n = g.add(f"n{i}", "op", deps=prev, meta={"dur": d})
        prev = [n.uid]
    return g


def test_serial_chain():
    g = make_chain([1.0, 2.0, 3.0])
    res = simulate(g, unit_duration)
    assert res.makespan == pytest.approx(6.0)


def test_parallel_independent_same_device():
    g = DataflowGraph("par")
    for i in range(4):
        g.add(f"n{i}", "op", meta={"dur": 1.0})
    res = simulate(g, unit_duration)
    # one compute device FIFO -> serialized
    assert res.makespan == pytest.approx(4.0)


def test_parallel_two_devices():
    g = DataflowGraph("par2")
    g.add("a", "op", meta={"dur": 3.0})
    g.add("b", "op", device="other", meta={"dur": 2.0})
    res = simulate(g, unit_duration)
    assert res.makespan == pytest.approx(3.0)
    assert res.device_busy["chip"] == pytest.approx(3.0)
    assert res.device_busy["other"] == pytest.approx(2.0)


def test_diamond_dependency():
    g = DataflowGraph("diamond")
    a = g.add("a", "op", meta={"dur": 1.0})
    b = g.add("b", "op", deps=[a.uid], device="d1", meta={"dur": 5.0})
    c = g.add("c", "op", deps=[a.uid], device="d2", meta={"dur": 2.0})
    g.add("d", "op", deps=[b.uid, c.uid], meta={"dur": 1.0})
    res = simulate(g, unit_duration)
    assert res.makespan == pytest.approx(7.0)  # 1 + max(5,2) + 1


def test_comm_overlaps_compute():
    """A collective on the link device overlaps independent compute."""
    g = DataflowGraph("overlap")
    a = g.add("a", "op", meta={"dur": 4.0})
    g.add(
        "ar", "all-reduce", comm_bytes=1.0, group_size=4, link_kind="ici",
        meta={"dur": 3.0},
    )
    res = simulate(g, unit_duration)
    assert res.makespan == pytest.approx(4.0)


# -- hypothesis property tests -------------------------------------------------


@st.composite
def random_dag(draw):
    n = draw(st.integers(1, 40))
    g = DataflowGraph("rand")
    for i in range(n):
        max_deps = min(i, 4)
        k = draw(st.integers(0, max_deps))
        deps = sorted(
            draw(
                st.lists(
                    st.integers(0, i - 1), min_size=k, max_size=k, unique=True
                )
            )
        ) if i > 0 else []
        dur = draw(st.floats(0.0, 10.0, allow_nan=False))
        dev = draw(st.sampled_from([None, "d1", "d2"]))
        g.add(f"n{i}", "op", deps=deps, device=dev, meta={"dur": dur})
    return g


@settings(max_examples=60, deadline=None)
@given(random_dag())
def test_makespan_bounds(g):
    res = simulate(g, unit_duration)
    total = sum(n.meta["dur"] for n in g.nodes)
    crit = g.critical_path(unit_duration)
    max_busy = max(res.device_busy.values(), default=0.0)
    assert res.makespan <= total + 1e-9          # never worse than serial
    assert res.makespan >= crit - 1e-9           # critical path lower bound
    assert res.makespan >= max_busy - 1e-9       # busiest device lower bound


@settings(max_examples=30, deadline=None)
@given(random_dag())
def test_determinism(g):
    r1 = simulate(g, unit_duration)
    r2 = simulate(g, unit_duration)
    assert r1.makespan == r2.makespan
    assert r1.device_busy == r2.device_busy


@settings(max_examples=30, deadline=None)
@given(random_dag(), st.floats(0.1, 10.0))
def test_adding_node_monotone(g, dur):
    """Appending a dependent node never reduces the makespan."""
    before = simulate(g, unit_duration).makespan
    deps = [len(g.nodes) - 1] if len(g.nodes) else []
    g.add("extra", "op", deps=deps, meta={"dur": dur})
    after = simulate(g, unit_duration).makespan
    assert after >= before - 1e-9


@settings(max_examples=30, deadline=None)
@given(random_dag())
def test_events_consistent(g):
    res = Simulator(unit_duration, record_events=True).run(g)
    # per-device events don't overlap and are ordered
    by_dev = {}
    for e in res.events:
        by_dev.setdefault(e.device, []).append(e)
    for evs in by_dev.values():
        evs.sort(key=lambda e: e.start)
        for a, b in zip(evs, evs[1:]):
            assert a.end <= b.start + 1e-9
