"""Pipeline schedule graphs + autotuner behavior."""
import pytest

from repro.configs.base import get_config
from repro.core.autotuner import Autotuner, layer_cost_from_config
from repro.core.graph import OpNode
from repro.core.simulator import simulate
from repro.core.strategy import LayerCost, Strategy, pipeline_graph


def const_duration(node: OpNode) -> float:
    if node.kind == "fwd":
        return 1.0
    if node.kind == "bwd":
        return 2.0
    return 0.0  # comm free


def test_gpipe_bubble_formula():
    """GPipe with zero comm: makespan = (M + S - 1)*t_f + (M + S - 1)*t_b."""
    S, M = 4, 8
    g = pipeline_graph(
        8, LayerCost(fwd_flops=1, fwd_bytes=0, boundary_bytes=0),
        Strategy(pp=S, microbatches=M, schedule="gpipe"),
    )
    res = simulate(g, const_duration)
    expect = (M + S - 1) * 1.0 + (M + S - 1) * 2.0
    assert res.makespan == pytest.approx(expect)


def test_1f1b_no_worse_than_gpipe():
    S, M = 4, 8
    cost = LayerCost(fwd_flops=1, fwd_bytes=0, boundary_bytes=0)
    g1 = pipeline_graph(8, cost, Strategy(pp=S, microbatches=M, schedule="1f1b"))
    g2 = pipeline_graph(8, cost, Strategy(pp=S, microbatches=M, schedule="gpipe"))
    m1 = simulate(g1, const_duration).makespan
    m2 = simulate(g2, const_duration).makespan
    assert m1 <= m2 + 1e-9


def test_more_microbatches_reduce_bubble():
    cost = LayerCost(fwd_flops=1, fwd_bytes=0, boundary_bytes=0)

    def bubble(M):
        g = pipeline_graph(
            8, cost, Strategy(pp=4, microbatches=M, schedule="gpipe")
        )
        res = simulate(g, const_duration)
        busy = max(
            t for d, t in res.device_busy.items() if d.startswith("stage")
        )
        return 1 - busy / res.makespan

    assert bubble(16) < bubble(2)


def test_grad_allreduce_appended():
    g = pipeline_graph(
        4,
        LayerCost(fwd_flops=1, fwd_bytes=0, boundary_bytes=0, grad_bytes=100),
        Strategy(dp=4, pp=2, microbatches=2),
    )
    kinds = [n.kind for n in g.nodes]
    assert kinds.count("all-reduce") == 2  # one per stage


def test_autotuner_prefers_parallelism():
    cfg = get_config("llama3.2-1b")
    tuner = Autotuner(cfg, chips=64, global_batch=256, seq=2048)
    results = tuner.search(microbatch_options=(1, 4, 8))
    assert len(results) > 3
    best, worst = results[0], results[-1]
    assert best.makespan_s < worst.makespan_s
    assert best.strategy.chips == 64


def test_autotuner_straggler_slows_pipeline():
    cfg = get_config("llama3.2-1b")
    tuner = Autotuner(cfg, chips=16, global_batch=64, seq=1024)
    cand = [s for s in tuner.candidates() if s.pp >= 2][0]
    base = tuner.evaluate(cand).makespan_s
    tuner.straggler_stage = 0
    tuner.straggler_factor = 3.0
    slow = tuner.evaluate(cand).makespan_s
    assert slow > base * 1.3


def test_layer_cost_positive():
    cfg = get_config("qwen3-moe-235b-a22b")
    c = layer_cost_from_config(cfg, batch=4, seq=2048, tp=16)
    assert c.fwd_flops > 0 and c.fwd_bytes > 0 and c.boundary_bytes > 0
