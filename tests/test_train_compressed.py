"""Compressed data-parallel training end-to-end.

The sim-vs-real loop for ``Strategy.compression``: the train step executes
int8 quantize -> psum -> dequantize with error-feedback residuals carried in
``TrainState.comp_state`` (under shard_map and standalone), the checkpoint
schema (format v2) round-trips the residuals and migrates v1, and the
simulator's annotated gradient all-reduce prices exactly the bytes the
executor's twin reports.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CKPT_FORMAT, restore, save
from repro.configs.base import get_config, smoke_shape, smoke_variant
from repro.core.estimator import dist_comm_bytes
from repro.core.graph import OpNode
from repro.core.strategy import LayerCost, Strategy, grad_allreduce_node_meta, pipeline_graph
from repro.dist.compress import (
    compressed_psum,
    compressed_psum_bytes,
    init_feedback_state,
    tree_allreduce_bytes,
)
from repro.models import build_model, make_concrete_batch
from repro.optim import adamw
from repro.train.step import (
    TrainState,
    init_state,
    make_sharded_train_step,
    make_train_step,
)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _smoke_setup(arch="llama3.2-1b"):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    opt = adamw()
    sched = lambda step: 1e-3
    batch = make_concrete_batch(cfg, smoke_shape("train"))
    return model, opt, sched, batch


def _data_mesh():
    return jax.make_mesh(
        (1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
    )


# -- executable train loop ----------------------------------------------------


def test_compressed_vs_dense_loss_trajectories_converge():
    """Compressed training must track dense training: both trajectories
    decrease and stay close (per-step int8 error is bounded by scale/2 and
    error feedback keeps it from accumulating)."""
    model, opt, sched, batch = _smoke_setup()
    dense_step = jax.jit(make_train_step(model, opt, sched))
    comp_step = jax.jit(
        make_sharded_train_step(model, opt, sched, _data_mesh(),
                                compression="int8")
    )
    s_d, _ = init_state(model, jax.random.PRNGKey(0), opt)
    s_c, _ = init_state(model, jax.random.PRNGKey(0), opt,
                        compression="int8", dp=1)
    dense, comp = [], []
    for _ in range(8):
        s_d, m_d = dense_step(s_d, batch)
        s_c, m_c = comp_step(s_c, batch)
        dense.append(float(m_d["loss"]))
        comp.append(float(m_c["loss"]))
    assert dense[-1] < dense[0] and comp[-1] < comp[0]
    for d, c in zip(dense, comp):
        assert c == pytest.approx(d, rel=0.05), (dense, comp)
    # the residual state is actually carried (nonzero after real steps)
    res_norm = sum(
        float(jnp.sum(jnp.abs(l)))
        for l in jax.tree_util.tree_leaves(s_c.comp_state)
    )
    assert res_norm > 0


def test_compressed_grad_accum_scan_path():
    """compression + grad_accum > 1: the scan path carries residuals AND
    the per-microbatch metric means (the accum path used to drop aux
    metrics entirely)."""
    model, opt, sched, batch = _smoke_setup()
    step = jax.jit(
        make_train_step(model, opt, sched, grad_accum=2, compression="int8")
    )
    state, _ = init_state(model, jax.random.PRNGKey(0), opt,
                          compression="int8", dp=1)
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    # model aux metrics survive accumulation
    assert "ce" in metrics and "aux" in metrics
    assert np.isfinite(float(metrics["ce"]))


def test_grad_accum_metrics_match_unaccumulated():
    """Mean-of-microbatch metrics == whole-batch metrics for the same
    params (the model's metrics are batch means)."""
    model, opt, sched, batch = _smoke_setup()
    step1 = jax.jit(make_train_step(model, opt, sched, grad_accum=1))
    step2 = jax.jit(make_train_step(model, opt, sched, grad_accum=2))
    s1, _ = init_state(model, jax.random.PRNGKey(0), opt)
    s2, _ = init_state(model, jax.random.PRNGKey(0), opt)
    _, m1 = step1(s1, batch)
    _, m2 = step2(s2, batch)
    assert float(m2["ce"]) == pytest.approx(float(m1["ce"]), rel=1e-4)
    assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=1e-4)


def test_residual_accumulation_unbiased_over_steps(rng):
    """Threaded through TrainState semantics: the sum of what the step
    actually applied (the dequantized means) plus the final residual equals
    the sum of the true gradients (dp=1)."""
    tree = lambda a: {"w": jnp.asarray(a, jnp.float32)}
    grads = [tree(rng.standard_normal(32)) for _ in range(25)]
    res = jax.tree_util.tree_map(lambda r: r[0], init_feedback_state(grads[0]))
    applied = jnp.zeros(32)
    for g in grads:
        mean, res = compressed_psum(g, None, res)
        applied = applied + mean["w"]
    total_true = sum(np.asarray(g["w"]) for g in grads)
    np.testing.assert_allclose(
        np.asarray(applied + res["w"]), total_true, rtol=1e-4, atol=1e-4
    )


# -- sim-vs-real byte parity --------------------------------------------------


def test_sim_bytes_equal_executor_twin_exactly():
    """Acceptance: estimator.dist_comm_bytes for the annotated strategy
    graph node == the executor byte twin for the same gradient pytree, with
    no tolerance."""
    model, *_ = _smoke_setup()
    shapes, _ = model.abstract_params()
    for scheme in ("int8", "topk:0.02"):
        twin = compressed_psum_bytes(shapes, scheme=scheme)
        meta = grad_allreduce_node_meta(shapes, scheme)
        node = OpNode(
            0, "gradAR", "all-reduce",
            comm_bytes=4.0 * meta["grad_elems"],
            group_size=8, link_kind="ici", meta=meta,
        )
        assert dist_comm_bytes(node) == twin
    # per-leaf accounting differs from the aggregate (one scale per tensor)
    meta = grad_allreduce_node_meta(shapes, "int8")
    assert meta["n_tensors"] == len(jax.tree_util.tree_leaves(shapes))
    assert tree_allreduce_bytes(meta["grad_leaf_elems"], "int8") == (
        meta["grad_elems"] + 4 * meta["n_tensors"]
    )


def test_pipeline_graph_n_tensors_flow_to_estimator():
    n_elems, n_tensors = 10_000, 7
    cost = LayerCost(fwd_flops=1e6, fwd_bytes=1e4,
                     grad_bytes=4.0 * n_elems, grad_tensors=n_tensors)
    g = pipeline_graph(4, cost, Strategy(dp=4, pp=2, microbatches=2,
                                         compression="int8"))
    ars = [n for n in g.nodes if n.kind == "all-reduce"]
    assert ars and all(n.meta["n_tensors"] == n_tensors for n in ars)
    assert all(
        dist_comm_bytes(n) == n_elems + 4 * n_tensors for n in ars
    )


# -- checkpoint schema v2 -----------------------------------------------------


def test_v2_checkpoint_roundtrips_residuals(tmp_path):
    model, opt, sched, batch = _smoke_setup()
    step = jax.jit(make_train_step(model, opt, sched, compression="int8"))
    state, _ = init_state(model, jax.random.PRNGKey(0), opt,
                          compression="int8", dp=1)
    for _ in range(2):
        state, _m = step(state, batch)
    save(state, str(tmp_path), step=2)
    man = json.load(open(tmp_path / "step_00000002" / "manifest.json"))
    assert man["format"] == CKPT_FORMAT
    assert any(k.startswith("comp_state/") for k in man["leaves"])
    out = restore(state, str(tmp_path))
    assert out is not None
    restored, at = out
    assert at == 2
    for a, b in zip(
        jax.tree_util.tree_leaves(state.comp_state),
        jax.tree_util.tree_leaves(restored.comp_state),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.any(np.asarray(a))  # residuals are real, not zeros


def test_v1_checkpoint_restores_into_v2_schema(tmp_path):
    """Acceptance: a v1 checkpoint (dotted attr keys, no format field, no
    comp_state) restores into the v2 TrainState with zero residuals."""
    model, opt, _sched, _batch = _smoke_setup()
    dense, _ = init_state(model, jax.random.PRNGKey(0), opt)
    save(dense, str(tmp_path), step=9)
    cdir = tmp_path / "step_00000009"
    man = json.load(open(cdir / "manifest.json"))
    del man["format"]
    # emulate the v1 writer: attribute path segments spelled str(GetAttrKey)
    v1 = {}
    for key, fname in man["leaves"].items():
        segs = key.split("/")
        segs[0] = "." + segs[0]  # step/params/opt_state are NamedTuple attrs
        old = "/".join(segs)
        old_fname = old.replace("/", "__") + ".npy"
        os.rename(cdir / fname, cdir / old_fname)
        v1[old] = old_fname
    man["leaves"] = v1
    json.dump(man, open(cdir / "manifest.json", "w"))

    like, _ = init_state(model, jax.random.PRNGKey(1), opt,
                         compression="int8", dp=2)
    out = restore(like, str(tmp_path))
    assert out is not None, "v1 -> v2 migration failed"
    restored, at = out
    assert at == 9
    for a, b in zip(
        jax.tree_util.tree_leaves(dense.params),
        jax.tree_util.tree_leaves(restored.params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for leaf in jax.tree_util.tree_leaves(restored.comp_state):
        assert leaf.shape[0] == 2 and not np.any(np.asarray(leaf))


def test_v2_dense_checkpoint_restores_into_compressed_schema(tmp_path):
    """A format-2 checkpoint written by a dense run (no comp_state leaves)
    must restore into a compressed TrainState with zero residuals — turning
    compression on mid-run resumes from the dense checkpoint instead of
    silently restarting at step 0."""
    model, opt, _sched, _batch = _smoke_setup()
    dense, _ = init_state(model, jax.random.PRNGKey(0), opt)
    save(dense, str(tmp_path), step=3)
    like, _ = init_state(model, jax.random.PRNGKey(1), opt,
                         compression="int8", dp=1)
    out = restore(like, str(tmp_path))
    assert out is not None, "dense v2 -> compressed restore failed"
    restored, at = out
    assert at == 3
    for a, b in zip(
        jax.tree_util.tree_leaves(dense.params),
        jax.tree_util.tree_leaves(restored.params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for leaf in jax.tree_util.tree_leaves(restored.comp_state):
        assert not np.any(np.asarray(leaf))


def test_v1_dense_state_keeps_v1_leaf_set(tmp_path):
    """comp_state=None is leafless: a dense v2 TrainState has the same
    leaves a v1 writer produced, so dense checkpoints stay interchangeable
    in both directions."""
    model, opt, _sched, _batch = _smoke_setup()
    dense, _ = init_state(model, jax.random.PRNGKey(0), opt)
    assert dense.comp_state is None
    n_with = len(jax.tree_util.tree_leaves(dense))
    legacy = TrainState(dense.step, dense.params, dense.opt_state)
    assert len(jax.tree_util.tree_leaves(legacy)) == n_with


# -- launcher end-to-end ------------------------------------------------------


def test_train_driver_compressed_end_to_end(tmp_path):
    """The full launch.train driver with --compression int8: trains, logs
    the comm report, checkpoints format v2, and the final state carries
    residuals."""
    from repro.launch.train import train

    cfg = smoke_variant(get_config("llama3.2-1b"))
    logs = []
    state, losses = train(
        cfg, steps=4, seq=32, batch=4, ckpt_dir=str(tmp_path),
        compression="int8", grad_accum=2, log_every=2, ckpt_every=10,
        log_fn=logs.append,
    )
    assert len(losses) == 4 and np.isfinite(losses).all()
    assert any("[comm]" in l and "ACTIVE" in l for l in logs)
    res_norm = sum(
        float(jnp.sum(jnp.abs(l)))
        for l in jax.tree_util.tree_leaves(state.comp_state)
    )
    assert res_norm > 0
    man = json.load(
        open(os.path.join(str(tmp_path), "step_00000004", "manifest.json"))
    )
    assert man["format"] == CKPT_FORMAT
    assert any(k.startswith("comp_state/") for k in man["leaves"])


# -- multi-device subprocess --------------------------------------------------

_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import types
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.compress import compressed_psum_bytes
    from repro.optim.optimizers import adamw
    from repro.train.step import (TrainState, make_sharded_train_step,
                                  make_train_step)
    from repro.dist.compress import init_feedback_state

    DP, B, D = 8, 4, 16
    rng = np.random.default_rng(0)
    w_true = rng.standard_normal(D).astype(np.float32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        err = pred - batch["y"]
        return jnp.mean(err * err), {"mse": jnp.mean(err * err)}

    model = types.SimpleNamespace(cfg=None, loss=loss_fn)
    opt = adamw()
    sched = lambda s: 0.1
    mesh = jax.make_mesh((DP,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    params = {"w": jnp.zeros((D,), jnp.float32), "b": jnp.zeros((), jnp.float32)}
    comp_step = jax.jit(make_sharded_train_step(
        model, opt, sched, mesh, grad_accum=2, compression="int8"))
    dense_step = jax.jit(make_train_step(model, opt, sched, grad_accum=2))

    s_c = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params),
                     init_feedback_state(params, DP))
    s_d = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))

    comp_losses, dense_losses = [], []
    for step in range(60):
        x = rng.standard_normal((DP * B, D)).astype(np.float32)
        y = x @ w_true + 0.01 * rng.standard_normal(DP * B).astype(np.float32)
        batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
        s_c, m_c = comp_step(s_c, batch)
        s_d, m_d = dense_step(s_d, batch)
        comp_losses.append(float(m_c["loss"]))
        dense_losses.append(float(m_d["loss"]))

    assert comp_losses[-1] < 0.2 * comp_losses[0], comp_losses
    # compressed DP over 8 real devices tracks exact dense training: the
    # global batch is identical, so the only gap is bounded int8 error
    assert abs(comp_losses[-1] - dense_losses[-1]) < 0.1 * dense_losses[0] + 0.05
    np.testing.assert_allclose(np.asarray(s_c.params["w"]),
                               np.asarray(s_d.params["w"]),
                               rtol=0.1, atol=0.05)
    # per-rank residuals: 8 independent slices, finite
    for leaf in jax.tree_util.tree_leaves(s_c.comp_state):
        assert leaf.shape[0] == DP
        assert np.isfinite(np.asarray(leaf)).all()
    # scan-path metrics survive on the real mesh too
    assert np.isfinite(float(m_c["mse"]))
    print("compressed_dp8_ok")
    """
)


@pytest.mark.slow
def test_compressed_training_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "compressed_dp8_ok" in out.stdout, (out.stdout, out.stderr[-1500:])
